//! Cross-module integration tests: scenario -> routing -> simulation ->
//! metrics, plus recovery and join behaviour end-to-end.  (PJRT-backed
//! integration lives in `runtime_integration.rs`.)

use std::sync::Arc;

use gwtf::baselines::{CostFn, DtfmRouter, GaParams, SwarmRouter};
use gwtf::coordinator::recovery::{plan_repair, RepairPlan};
use gwtf::coordinator::GwtfRouter;
use gwtf::flow::decentralized::{DecentralizedFlow, FlowParams};
use gwtf::flow::graph::validate_paths;
use gwtf::flow::mcmf::mcmf_min_cost;
use gwtf::metrics::MetricsTable;
use gwtf::sim::engine::Engine;
use gwtf::sim::scenario::{build, ScenarioConfig};
use gwtf::sim::training::{
    BlockingPlanAdapter, PlanOutcome, PlanRequest, PlanTicket, RoutingPolicy,
};

fn run_system(
    sc: &gwtf::sim::scenario::Scenario,
    router: &mut dyn RoutingPolicy,
    iters: usize,
    seed: u64,
) -> Vec<gwtf::sim::IterationMetrics> {
    run_engine(sc, router, iters, seed, false)
}

fn run_engine(
    sc: &gwtf::sim::scenario::Scenario,
    router: &mut dyn RoutingPolicy,
    iters: usize,
    seed: u64,
    warm_replan: bool,
) -> Vec<gwtf::sim::IterationMetrics> {
    let mut engine = Engine::from_scenario(sc, seed);
    engine.warm_replan = warm_replan;
    (0..iters).map(|_| engine.step(&sc.prob, router)).collect()
}

#[test]
fn gwtf_full_iteration_fault_free() {
    let sc = build(&ScenarioConfig::table2(true, 0.0, 3));
    let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 3);
    let ms = run_system(&sc, &mut router, 3, 3);
    for m in &ms {
        assert_eq!(m.completed, 8, "all 2x4 microbatches complete");
        assert_eq!(m.dropped, 0);
        assert_eq!(m.wasted_gpu_s, 0.0);
        assert_eq!(m.denies, 0, "capacity-aware plan never overloads");
        assert!(m.makespan_s > 0.0 && m.makespan_s.is_finite());
    }
}

#[test]
fn gwtf_survives_heavy_churn_without_panic() {
    let sc = build(&ScenarioConfig::table2(false, 0.3, 11));
    let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 11);
    let ms = run_system(&sc, &mut router, 10, 11);
    assert_eq!(ms.len(), 10);
    // at 30% churn some iterations complete work, some may not; the run
    // must stay finite and deterministic
    assert!(ms.iter().any(|m| m.completed > 0));
}

#[test]
fn swarm_pays_denies_under_capacity_pressure() {
    let sc = build(&ScenarioConfig::table2(false, 0.0, 5));
    let topo = sc.topo.clone();
    let payload = sc.sim_cfg.payload_bytes;
    let comm: CostFn = Arc::new(move |i, j| topo.comm(i, j, payload));
    let mut router = BlockingPlanAdapter::new(SwarmRouter::from_problem(&sc.prob, comm, 5));
    let ms = run_system(&sc, &mut router, 3, 5);
    let denies: usize = ms.iter().map(|m| m.denies).sum();
    assert!(denies > 0, "capacity-oblivious wiring must hit memory DENYs");
}

#[test]
fn deterministic_end_to_end() {
    let run = || {
        let sc = build(&ScenarioConfig::table2(false, 0.2, 7));
        let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 7);
        run_system(&sc, &mut router, 5, 7)
            .iter()
            .map(|m| (m.completed, m.makespan_s))
            .collect::<Vec<_>>()
    };
    assert_eq!(run(), run());
}

#[test]
fn repair_policy_beats_restart_policy_under_churn() {
    // DESIGN.md §7 ablation: same scenario/churn, only the backward
    // recovery policy differs.  Wasted GPU time must favour path repair.
    struct Restarting(GwtfRouter);
    impl RoutingPolicy for Restarting {
        fn name(&self) -> String {
            "gwtf-restart".into()
        }
        fn request_plan(&mut self, req: &PlanRequest) -> PlanTicket {
            self.0.request_plan(req)
        }
        fn commit_plan(
            &mut self,
            ticket: &PlanTicket,
            invalidated: &[gwtf::cost::NodeId],
        ) -> PlanOutcome {
            self.0.commit_plan(ticket, invalidated)
        }
        fn on_crash(&mut self, n: gwtf::cost::NodeId) {
            self.0.on_crash(n)
        }
        fn choose_replacement(
            &mut self,
            prev: gwtf::cost::NodeId,
            next: gwtf::cost::NodeId,
            c: &[gwtf::cost::NodeId],
        ) -> Option<gwtf::cost::NodeId> {
            self.0.choose_replacement(prev, next, c)
        }
        fn recovery(&self) -> gwtf::sim::RecoveryPolicy {
            gwtf::sim::RecoveryPolicy::RestartPipeline
        }
    }

    let mut wasted_repair = 0.0;
    let mut wasted_restart = 0.0;
    for seed in 0..8 {
        let sc = build(&ScenarioConfig::table2(true, 0.15, 100 + seed));
        let mut repair = GwtfRouter::from_scenario(&sc, FlowParams::default(), seed);
        wasted_repair += run_system(&sc, &mut repair, 4, seed)
            .iter()
            .map(|m| m.wasted_gpu_s)
            .sum::<f64>();
        let mut restart =
            Restarting(GwtfRouter::from_scenario(&sc, FlowParams::default(), seed));
        wasted_restart += run_system(&sc, &mut restart, 4, seed)
            .iter()
            .map(|m| m.wasted_gpu_s)
            .sum::<f64>();
    }
    assert!(
        wasted_repair <= wasted_restart,
        "repair wasted {wasted_repair} vs restart {wasted_restart}"
    );
}

#[test]
fn dtfm_arrangement_feeds_simulator() {
    let sc = build(&ScenarioConfig::table6(13));
    let topo = sc.topo.clone();
    let payload = sc.sim_cfg.payload_bytes;
    let cost: CostFn = Arc::new(move |i, j| topo.cost(i, j, payload));
    let mut router = DtfmRouter::new(
        sc.prob.graph.clone(),
        sc.prob.demand.clone(),
        cost,
        GaParams { generations: 40, ..Default::default() },
        13,
    );
    let ms = run_system(&sc, &mut router, 2, 13);
    assert_eq!(ms[0].completed, 12, "3 pipelines x 4 microbatches");
    assert!(ms[0].planning_s > 0.0, "GA time charged");
    assert_eq!(ms[1].planning_s, 0.0, "arrangement cached");
}

#[test]
fn decentralized_flow_validates_against_problem_and_optimum() {
    for seed in 0..5 {
        let sc = build(&ScenarioConfig::table2(false, 0.0, 40 + seed));
        let params = FlowParams { minmax_objective: false, ..FlowParams::default() };
        let mut f = DecentralizedFlow::new(&sc.prob, params, seed);
        f.run(120, 10);
        let paths = f.established_paths();
        validate_paths(&paths, &sc.prob).unwrap();
        let opt = mcmf_min_cost(&sc.prob);
        assert!(paths.len() <= opt.flow, "cannot beat max-flow");
        // routes at least 60% of the optimum's flow on these instances
        assert!(
            paths.len() * 10 >= opt.flow * 6,
            "routed {} of optimal {}",
            paths.len(),
            opt.flow
        );
    }
}

#[test]
fn repair_planner_consistent_with_routed_paths() {
    // if plan_repair says Repaired, the new path must remain stage-valid
    let sc = build(&ScenarioConfig::table2(true, 0.0, 21));
    let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 21);
    let alive = vec![true; sc.topo.n()];
    let (paths, _) = router.plan(&alive);
    let victim = paths[0].relays[2];
    let topo = sc.topo.clone();
    let payload = sc.sim_cfg.payload_bytes;
    let plan = plan_repair(
        &paths[0],
        &sc.prob.graph,
        |n| n != victim,
        |_| true,
        |i, j| topo.cost(i, j, payload),
    );
    match plan {
        RepairPlan::Repaired { path, replacements, .. } => {
            assert_eq!(replacements.len(), 1);
            assert!(!path.relays.contains(&victim));
            assert!(sc.prob.graph.stages[2].contains(&path.relays[2]));
        }
        p => panic!("expected repair, got {p:?}"),
    }
}

#[test]
fn metrics_table_roundtrip_files() {
    let sc = build(&ScenarioConfig::table2(true, 0.1, 31));
    let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 31);
    let ms = run_system(&sc, &mut router, 3, 31);
    let mut table = MetricsTable::new("integration");
    for m in &ms {
        table.cell("homog 10%", "gwtf").push(m);
    }
    let dir = std::env::temp_dir().join("gwtf_integration_report");
    table.write(&dir, "it").unwrap();
    let md = std::fs::read_to_string(dir.join("it.md")).unwrap();
    assert!(md.contains("homog 10%"));
    let csv = std::fs::read_to_string(dir.join("it.csv")).unwrap();
    assert!(csv.contains("throughput"));
}

#[test]
fn warm_replan_engine_survives_churn_and_is_deterministic() {
    let run = || {
        let sc = build(&ScenarioConfig::table2(false, 0.2, 19));
        let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 19);
        run_engine(&sc, &mut router, 6, 19, /*warm_replan=*/ true)
            .iter()
            .map(|m| (m.completed, m.makespan_s.to_bits(), m.comm_s.to_bits()))
            .collect::<Vec<_>>()
    };
    let a = run();
    assert!(a.iter().any(|&(completed, _, _)| completed > 0));
    assert_eq!(a, run(), "warm-replan engine must be deterministic from seeds");
}

#[test]
fn continuous_time_scenarios_run_from_experiments() {
    use gwtf::experiments::{run_link_jitter, run_mid_agg_crash, ScenarioOpts};
    let opts = ScenarioOpts { reps: 1, iters_per_rep: 3, seed: 23 };

    let midagg = run_mid_agg_crash(&opts).unwrap();
    let row = "table2 homogeneous".to_string();
    let crash = &midagg.cells[&(row.clone(), "midagg-crash".to_string())];
    assert_eq!(crash.agg_recoveries.iter().sum::<f64>(), 1.0, "one barrier recovery");
    let clean = &midagg.cells[&(row, "no-crash".to_string())];
    assert_eq!(clean.agg_recoveries.iter().sum::<f64>(), 0.0);
    // The two runs are identical up to the crash iteration (index 1);
    // that iteration pays the barrier re-exchange on top.
    assert_eq!(crash.makespan_min[0].to_bits(), clean.makespan_min[0].to_bits());
    assert!(
        crash.makespan_min[1] > clean.makespan_min[1],
        "crash iteration {} vs clean {}",
        crash.makespan_min[1],
        clean.makespan_min[1]
    );

    let jitter = run_link_jitter(&opts).unwrap();
    let mk = |row: &str| -> f64 {
        jitter.cells[&(row.to_string(), "gwtf".to_string())].makespan_min.iter().sum()
    };
    assert!(
        (mk("jitter 50%") - mk("jitter 0%")).abs() > 1e-9,
        "jitter windows must perturb the timeline"
    );
    for row in ["jitter 0%", "jitter 25%", "jitter 50%"] {
        let acc = &jitter.cells[&(row.to_string(), "gwtf".to_string())];
        assert!(acc.throughput.iter().sum::<f64>() > 0.0, "{row}");
    }
}

/// The ISSUE-1 replan bench, test-sized: cold re-plan vs warm-start
/// re-plan across churn rates, plus the single-crash headline case.
/// Records measured rounds + wall time to BENCH_flow_replan.json at the
/// repo root (the full version is `cargo bench --bench replan_bench`).
#[test]
fn warm_replan_beats_cold_and_records_bench_json() {
    use gwtf::cost::NodeId;
    use std::fmt::Write as _;
    use std::time::Instant;

    let mut cases = String::new();

    // --- headline: a single crash on an established plan ---
    let sc = build(&ScenarioConfig::table2(true, 0.0, 31));
    let n = sc.topo.n();
    let mut cold = GwtfRouter::from_scenario(&sc, FlowParams::default(), 31);
    let mut warm = GwtfRouter::from_scenario(&sc, FlowParams::default(), 31);
    let mut alive = vec![true; n];
    let (paths, _) = cold.plan(&alive);
    warm.plan(&alive);
    let victim = paths[0].relays[1];
    alive[victim.0] = false;

    let t0 = Instant::now();
    let (cold_paths, _) = cold.plan(&alive);
    let cold_ms = t0.elapsed().as_secs_f64() * 1e3;
    let cold_rounds = cold.last_rounds;

    let t0 = Instant::now();
    let (warm_paths, _) = warm.replan(&alive, &[victim]);
    let warm_ms = t0.elapsed().as_secs_f64() * 1e3;
    let warm_rounds = warm.last_rounds;

    assert_eq!(warm_paths.len(), cold_paths.len(), "same routed demand");
    validate_paths(&warm_paths, &sc.prob).unwrap();
    for p in &warm_paths {
        assert!(!p.relays.contains(&victim));
    }
    assert!(
        warm_rounds < cold_rounds,
        "single crash: warm {warm_rounds} rounds vs cold {cold_rounds}"
    );
    writeln!(
        cases,
        "    {{\"case\": \"single-crash\", \"cold_rounds\": {cold_rounds}, \
         \"warm_rounds\": {warm_rounds}, \"cold_ms\": {cold_ms:.3}, \
         \"warm_ms\": {warm_ms:.3}}},"
    )
    .unwrap();

    // --- churn-rate sweep: 0% / 10% / 20%, summed over iterations ---
    for &rate in &[0.0, 0.1, 0.2] {
        let sc = build(&ScenarioConfig::table2(false, rate, 77));
        let n = sc.topo.n();
        let mut cold = GwtfRouter::from_scenario(&sc, FlowParams::default(), 7);
        let mut warm = GwtfRouter::from_scenario(&sc, FlowParams::default(), 7);
        let mut churn = sc.churn.clone();
        let mut prev = vec![true; n];
        cold.plan(&prev);
        warm.plan(&prev);
        let (mut cold_rounds, mut warm_rounds) = (0usize, 0usize);
        let (mut cold_ms, mut warm_ms) = (0.0f64, 0.0f64);
        let iters = 6;
        for _ in 0..iters {
            let ev = churn.sample_iteration();
            let alive = churn.planning_view(&ev);
            let dirty: Vec<NodeId> = (0..n)
                .filter(|&i| prev[i] && !alive[i])
                .map(NodeId)
                .collect();

            let t0 = Instant::now();
            cold.plan(&alive);
            cold_ms += t0.elapsed().as_secs_f64() * 1e3;
            cold_rounds += cold.last_rounds;

            let t0 = Instant::now();
            let (wp, _) = warm.replan(&alive, &dirty);
            warm_ms += t0.elapsed().as_secs_f64() * 1e3;
            warm_rounds += warm.last_rounds;

            validate_paths(&wp, &sc.prob).unwrap();
            for p in &wp {
                for &r in &p.relays {
                    assert!(alive[r.0], "dead relay {r} routed at churn {rate}");
                }
            }
            prev = alive;
        }
        assert!(
            warm_rounds <= cold_rounds,
            "churn {rate}: warm {warm_rounds} rounds vs cold {cold_rounds}"
        );
        writeln!(
            cases,
            "    {{\"churn\": {rate}, \"iters\": {iters}, \"cold_rounds\": {cold_rounds}, \
             \"warm_rounds\": {warm_rounds}, \"cold_ms\": {cold_ms:.3}, \
             \"warm_ms\": {warm_ms:.3}}},"
        )
        .unwrap();
    }

    let cases = cases.trim_end().trim_end_matches(',').to_string();
    let json = format!(
        "{{\n  \"bench\": \"flow_replan\",\n  \"scenario\": \"table2, 18 nodes, 6 stages\",\n  \
         \"source\": \"rust/tests/integration.rs (test-sized; full: cargo bench --bench replan_bench)\",\n  \
         \"cases\": [\n{cases}\n  ]\n}}\n"
    );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_flow_replan.json");
    std::fs::write(path, json).unwrap();
}

#[test]
fn join_then_route_increases_throughput() {
    // growing the bottleneck stage must never reduce routable flow
    use gwtf::baselines::{JoinExperiment, JoinSetting};
    let setting = JoinSetting::setting(1).reduced();
    let exp = JoinExperiment::generate(&setting, 77);
    let before = mcmf_min_cost(&exp.problem());
    let out = exp.run(gwtf::baselines::JoinPolicyExt::Gwtf);
    assert!(out.cost_after <= out.cost_before);
    let _ = before;
}
