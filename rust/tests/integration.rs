//! Cross-module integration tests: scenario -> routing -> simulation ->
//! metrics, plus recovery and join behaviour end-to-end.  (PJRT-backed
//! integration lives in `runtime_integration.rs`.)

use std::sync::Arc;

use gwtf::baselines::{CostFn, DtfmRouter, GaParams, SwarmRouter};
use gwtf::coordinator::recovery::{plan_repair, RepairPlan};
use gwtf::coordinator::GwtfRouter;
use gwtf::flow::decentralized::{DecentralizedFlow, FlowParams};
use gwtf::flow::graph::validate_paths;
use gwtf::flow::mcmf::mcmf_min_cost;
use gwtf::metrics::MetricsTable;
use gwtf::sim::scenario::{build, ScenarioConfig};
use gwtf::sim::training::{Router, TrainingSim};
use gwtf::util::Rng;

fn run_system(
    sc: &gwtf::sim::scenario::Scenario,
    router: &mut dyn Router,
    iters: usize,
    seed: u64,
) -> Vec<gwtf::sim::IterationMetrics> {
    let mut sim = TrainingSim::new(sc.topo.clone(), sc.sim_cfg.clone());
    let mut churn = sc.churn.clone();
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for _ in 0..iters {
        let ev = churn.sample_iteration();
        let alive = churn.planning_view(&ev);
        let (paths, planning) = router.plan(&alive);
        out.push(sim.run_iteration(&sc.prob, router, &ev, &churn, planning, paths, &mut rng));
    }
    out
}

#[test]
fn gwtf_full_iteration_fault_free() {
    let sc = build(&ScenarioConfig::table2(true, 0.0, 3));
    let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 3);
    let ms = run_system(&sc, &mut router, 3, 3);
    for m in &ms {
        assert_eq!(m.completed, 8, "all 2x4 microbatches complete");
        assert_eq!(m.dropped, 0);
        assert_eq!(m.wasted_gpu_s, 0.0);
        assert_eq!(m.denies, 0, "capacity-aware plan never overloads");
        assert!(m.makespan_s > 0.0 && m.makespan_s.is_finite());
    }
}

#[test]
fn gwtf_survives_heavy_churn_without_panic() {
    let sc = build(&ScenarioConfig::table2(false, 0.3, 11));
    let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 11);
    let ms = run_system(&sc, &mut router, 10, 11);
    assert_eq!(ms.len(), 10);
    // at 30% churn some iterations complete work, some may not; the run
    // must stay finite and deterministic
    assert!(ms.iter().any(|m| m.completed > 0));
}

#[test]
fn swarm_pays_denies_under_capacity_pressure() {
    let sc = build(&ScenarioConfig::table2(false, 0.0, 5));
    let topo = sc.topo.clone();
    let payload = sc.sim_cfg.payload_bytes;
    let comm: CostFn = Arc::new(move |i, j| topo.comm(i, j, payload));
    let mut router = SwarmRouter::from_problem(&sc.prob, comm, 5);
    let ms = run_system(&sc, &mut router, 3, 5);
    let denies: usize = ms.iter().map(|m| m.denies).sum();
    assert!(denies > 0, "capacity-oblivious wiring must hit memory DENYs");
}

#[test]
fn deterministic_end_to_end() {
    let run = || {
        let sc = build(&ScenarioConfig::table2(false, 0.2, 7));
        let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 7);
        run_system(&sc, &mut router, 5, 7)
            .iter()
            .map(|m| (m.completed, m.makespan_s))
            .collect::<Vec<_>>()
    };
    assert_eq!(run(), run());
}

#[test]
fn repair_policy_beats_restart_policy_under_churn() {
    // DESIGN.md §7 ablation: same scenario/churn, only the backward
    // recovery policy differs.  Wasted GPU time must favour path repair.
    struct Restarting(GwtfRouter);
    impl Router for Restarting {
        fn name(&self) -> String {
            "gwtf-restart".into()
        }
        fn plan(&mut self, alive: &[bool]) -> (Vec<gwtf::flow::graph::FlowPath>, f64) {
            self.0.plan(alive)
        }
        fn on_crash(&mut self, n: gwtf::cost::NodeId) {
            self.0.on_crash(n)
        }
        fn choose_replacement(
            &mut self,
            prev: gwtf::cost::NodeId,
            next: gwtf::cost::NodeId,
            stage: usize,
            sink: gwtf::cost::NodeId,
            c: &[gwtf::cost::NodeId],
        ) -> Option<gwtf::cost::NodeId> {
            self.0.choose_replacement(prev, next, stage, sink, c)
        }
        fn recovery(&self) -> gwtf::sim::RecoveryPolicy {
            gwtf::sim::RecoveryPolicy::RestartPipeline
        }
    }

    let mut wasted_repair = 0.0;
    let mut wasted_restart = 0.0;
    for seed in 0..8 {
        let sc = build(&ScenarioConfig::table2(true, 0.15, 100 + seed));
        let mut repair = GwtfRouter::from_scenario(&sc, FlowParams::default(), seed);
        wasted_repair += run_system(&sc, &mut repair, 4, seed)
            .iter()
            .map(|m| m.wasted_gpu_s)
            .sum::<f64>();
        let mut restart =
            Restarting(GwtfRouter::from_scenario(&sc, FlowParams::default(), seed));
        wasted_restart += run_system(&sc, &mut restart, 4, seed)
            .iter()
            .map(|m| m.wasted_gpu_s)
            .sum::<f64>();
    }
    assert!(
        wasted_repair <= wasted_restart,
        "repair wasted {wasted_repair} vs restart {wasted_restart}"
    );
}

#[test]
fn dtfm_arrangement_feeds_simulator() {
    let sc = build(&ScenarioConfig::table6(13));
    let topo = sc.topo.clone();
    let payload = sc.sim_cfg.payload_bytes;
    let cost: CostFn = Arc::new(move |i, j| topo.cost(i, j, payload));
    let mut router = DtfmRouter::new(
        sc.prob.graph.clone(),
        sc.prob.demand.clone(),
        cost,
        GaParams { generations: 40, ..Default::default() },
        13,
    );
    let ms = run_system(&sc, &mut router, 2, 13);
    assert_eq!(ms[0].completed, 12, "3 pipelines x 4 microbatches");
    assert!(ms[0].planning_s > 0.0, "GA time charged");
    assert_eq!(ms[1].planning_s, 0.0, "arrangement cached");
}

#[test]
fn decentralized_flow_validates_against_problem_and_optimum() {
    for seed in 0..5 {
        let sc = build(&ScenarioConfig::table2(false, 0.0, 40 + seed));
        let params = FlowParams { minmax_objective: false, ..FlowParams::default() };
        let mut f = DecentralizedFlow::new(&sc.prob, params, seed);
        f.run(120, 10);
        let paths = f.established_paths();
        validate_paths(&paths, &sc.prob).unwrap();
        let opt = mcmf_min_cost(&sc.prob);
        assert!(paths.len() <= opt.flow, "cannot beat max-flow");
        // routes at least 60% of the optimum's flow on these instances
        assert!(
            paths.len() * 10 >= opt.flow * 6,
            "routed {} of optimal {}",
            paths.len(),
            opt.flow
        );
    }
}

#[test]
fn repair_planner_consistent_with_routed_paths() {
    // if plan_repair says Repaired, the new path must remain stage-valid
    let sc = build(&ScenarioConfig::table2(true, 0.0, 21));
    let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 21);
    let alive = vec![true; sc.topo.n()];
    let (paths, _) = router.plan(&alive);
    let victim = paths[0].relays[2];
    let topo = sc.topo.clone();
    let payload = sc.sim_cfg.payload_bytes;
    let plan = plan_repair(
        &paths[0],
        &sc.prob.graph,
        |n| n != victim,
        |_| true,
        |i, j| topo.cost(i, j, payload),
    );
    match plan {
        RepairPlan::Repaired { path, replacements, .. } => {
            assert_eq!(replacements.len(), 1);
            assert!(!path.relays.contains(&victim));
            assert!(sc.prob.graph.stages[2].contains(&path.relays[2]));
        }
        p => panic!("expected repair, got {p:?}"),
    }
}

#[test]
fn metrics_table_roundtrip_files() {
    let sc = build(&ScenarioConfig::table2(true, 0.1, 31));
    let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 31);
    let ms = run_system(&sc, &mut router, 3, 31);
    let mut table = MetricsTable::new("integration");
    for m in &ms {
        table.cell("homog 10%", "gwtf").push(m);
    }
    let dir = std::env::temp_dir().join("gwtf_integration_report");
    table.write(&dir, "it").unwrap();
    let md = std::fs::read_to_string(dir.join("it.md")).unwrap();
    assert!(md.contains("homog 10%"));
    let csv = std::fs::read_to_string(dir.join("it.csv")).unwrap();
    assert!(csv.contains("throughput"));
}

#[test]
fn join_then_route_increases_throughput() {
    // growing the bottleneck stage must never reduce routable flow
    use gwtf::baselines::{JoinExperiment, JoinSetting};
    let setting = JoinSetting::setting(1).reduced();
    let exp = JoinExperiment::generate(&setting, 77);
    let before = mcmf_min_cost(&exp.problem());
    let out = exp.run(gwtf::baselines::JoinPolicyExt::Gwtf);
    assert!(out.cost_after <= out.cost_before);
    let _ = before;
}
