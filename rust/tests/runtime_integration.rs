//! PJRT-backed integration tests: artifacts -> runtime -> trainer.
//!
//! These need `make artifacts`; each test skips (with a note) when the
//! manifest is missing so `cargo test` stays green on a fresh checkout.

use std::sync::Arc;

use gwtf::runtime::{BlockStage, DataNodeModel, HostTensor, Manifest, Runtime};
use gwtf::trainer::PipelineTrainer;

fn manifest() -> Option<Manifest> {
    match Manifest::load(Manifest::default_dir()) {
        Ok(m) => Some(m),
        Err(_) => {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn artifacts_compile_and_declare_consistent_shapes() {
    let Some(m) = manifest() else { return };
    let rt = Runtime::cpu().unwrap();
    for fam_name in ["llama", "gpt"] {
        let fam = m.family(fam_name).unwrap();
        let cfg = &fam.config;
        assert!(cfg.n_stages >= 1);
        // activation spec matches config dims on the stage boundary
        let e = fam.entry("stage_fwd").unwrap();
        let act = e.inputs.last().unwrap();
        assert_eq!(act.shape, vec![cfg.microbatch, cfg.seq_len, cfg.d_model], "{fam_name}");
        // every artifact compiles
        for entry in fam.entries.values() {
            rt.load(entry).unwrap_or_else(|err| panic!("{fam_name}/{}: {err:#}", entry.name));
        }
    }
}

#[test]
fn stage_roundtrip_shapes_and_determinism() {
    let Some(m) = manifest() else { return };
    let fam = m.family("llama").unwrap().clone();
    let cfg = fam.config.clone();
    let rt = Arc::new(Runtime::cpu().unwrap());
    let stage = BlockStage::init(rt.clone(), &fam, 0, 7).unwrap();

    let n = cfg.microbatch * cfg.seq_len * cfg.d_model;
    let x = HostTensor::f32(
        vec![cfg.microbatch, cfg.seq_len, cfg.d_model],
        (0..n).map(|i| ((i % 31) as f32 - 15.0) * 1e-2).collect(),
    );
    let y1 = stage.forward(&x).unwrap();
    let y2 = stage.forward(&x).unwrap();
    assert_eq!(y1.shape(), x.shape());
    assert_eq!(y1, y2, "stage forward must be deterministic");
    // finite output
    assert!(y1.as_f32().unwrap().iter().all(|v| v.is_finite()));

    // backward returns one grad leaf per param leaf + dx
    let (grads, dx) = stage.backward(&x, &y1).unwrap();
    assert_eq!(grads.len(), stage.params.len());
    assert_eq!(dx.shape(), x.shape());
}

#[test]
fn init_is_seeded_and_distinct() {
    let Some(m) = manifest() else { return };
    let fam = m.family("gpt").unwrap().clone();
    let rt = Arc::new(Runtime::cpu().unwrap());
    let a = BlockStage::init(rt.clone(), &fam, 0, 1).unwrap();
    let b = BlockStage::init(rt.clone(), &fam, 0, 1).unwrap();
    let c = BlockStage::init(rt.clone(), &fam, 0, 2).unwrap();
    assert_eq!(a.params, b.params, "same seed, same params");
    assert_ne!(a.params, c.params, "different seed, different params");
}

#[test]
fn sgd_update_moves_params_against_gradient() {
    let Some(m) = manifest() else { return };
    let fam = m.family("llama").unwrap().clone();
    let cfg = fam.config.clone();
    let rt = Arc::new(Runtime::cpu().unwrap());
    let mut data_node = DataNodeModel::init(rt.clone(), &fam, 3).unwrap();

    let tokens = HostTensor::i32(
        vec![cfg.microbatch, cfg.seq_len],
        (0..cfg.microbatch * cfg.seq_len).map(|i| (i % cfg.vocab_size) as i32).collect(),
    );
    let targets = tokens.clone();
    let x = data_node.embed(&tokens).unwrap();
    let loss_before = data_node.loss(&x, &targets).unwrap();
    let (head_grads, _dx, loss) = data_node.head_backward(&x, &targets).unwrap();
    assert!((loss - loss_before).abs() < 1e-4);

    data_node.update_head(&head_grads, 0.5).unwrap();
    let loss_after = data_node.loss(&x, &targets).unwrap();
    assert!(
        loss_after < loss_before,
        "one SGD step on the head must reduce loss: {loss_before} -> {loss_after}"
    );
}

#[test]
fn trainer_overfits_fixed_batch_and_is_deterministic() {
    let Some(_m) = manifest() else { return };
    let run = || {
        let mut t =
            PipelineTrainer::new(Manifest::default_dir(), "llama", 42, 0.5, 2).unwrap();
        // fixed batch: repeated steps must strictly reduce its loss
        let batch = t.batches.next_batch();
        let batches = vec![batch];
        let mut losses = Vec::new();
        for _ in 0..4 {
            losses.push(t.step_on(&batches).unwrap().loss);
        }
        losses
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "training must be deterministic from the seed");
    for w in a.windows(2) {
        assert!(w[1] < w[0], "overfit loss must fall monotonically: {a:?}");
    }
}

#[test]
fn gpt_and_llama_families_both_train() {
    let Some(_m) = manifest() else { return };
    for family in ["llama", "gpt"] {
        let mut t =
            PipelineTrainer::new(Manifest::default_dir(), family, 7, 0.25, 1).unwrap();
        let m1 = t.step().unwrap();
        assert!(m1.loss.is_finite() && m1.loss > 0.0, "{family}: {}", m1.loss);
    }
}
