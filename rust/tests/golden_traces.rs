//! Golden-trace regression tests for the continuous-time scenarios.
//!
//! The `midagg` and `jitter` experiments are re-run with fixed, test-sized
//! options and their per-iteration metric traces are diffed bit-for-bit
//! against committed JSON fixtures under `rust/tests/fixtures/` — the same
//! guard-rail role PR 1's manual-loop parity assert played for the engine
//! extraction, but end-to-end through scenario building, routing and the
//! metrics accumulators.
//!
//! If a fixture is missing (first run on a fresh machine), the test
//! captures the current trace, writes the fixture and passes with a
//! notice — commit the generated file to arm the guard.  To intentionally
//! re-baseline after a behaviour change, delete the fixture (or run with
//! `GWTF_UPDATE_GOLDEN=1`) and re-run `cargo test`.
//!
//! Floats are stored as hex `f64::to_bits` strings so the comparison is
//! exact and immune to JSON number round-tripping.  Caveat: the traces
//! flow through libm transcendentals (`exp`/`ln`/`cos`/`powf` in the
//! annealer, RNG normals and corpus shaping), which are not bit-identical
//! across libm implementations — fixtures are therefore *per-platform*
//! baselines.  Capture them on the canonical Linux/glibc CI environment;
//! on a different libm (e.g. macOS), regenerate locally with
//! `GWTF_UPDATE_GOLDEN=1` rather than committing.

use std::collections::BTreeMap;
use std::path::PathBuf;

use gwtf::experiments::{run_link_jitter, run_mid_agg_crash, ScenarioOpts};
use gwtf::metrics::MetricsTable;
use gwtf::util::json::Json;

fn opts() -> ScenarioOpts {
    ScenarioOpts { reps: 2, iters_per_rep: 3, seed: 7 }
}

fn fixture_path(name: &str) -> PathBuf {
    PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures"))
        .join(format!("{name}.json"))
}

fn bits_arr(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|x| Json::Str(format!("{:016x}", x.to_bits()))).collect())
}

fn num_arr(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

/// Serialize the per-iteration trace of every cell (deterministic order:
/// the table's BTreeMap).
fn trace_json(t: &MetricsTable) -> Json {
    let mut cells = BTreeMap::new();
    for ((row, col), acc) in &t.cells {
        let mut obj = BTreeMap::new();
        obj.insert("throughput".to_string(), num_arr(&acc.throughput));
        obj.insert("agg_recoveries".to_string(), num_arr(&acc.agg_recoveries));
        obj.insert("makespan_min_bits".to_string(), bits_arr(&acc.makespan_min));
        obj.insert("comm_time_min_bits".to_string(), bits_arr(&acc.comm_time_min));
        obj.insert("wasted_gpu_min_bits".to_string(), bits_arr(&acc.wasted_gpu_min));
        cells.insert(format!("{row} | {col}"), Json::Obj(obj));
    }
    let mut root = BTreeMap::new();
    root.insert("cells".to_string(), Json::Obj(cells));
    Json::Obj(root)
}

fn check_golden(name: &str, t: &MetricsTable) {
    let got = trace_json(t);
    let path = fixture_path(name);
    let update = std::env::var("GWTF_UPDATE_GOLDEN").is_ok();
    if update || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, format!("{got}\n")).unwrap();
        // A CI runner starts from a fresh checkout, so an uncommitted
        // fixture means the guard is NOT armed there — shout about it
        // (the authoring container for this test had no toolchain, so the
        // initial capture has to happen on a checkout that can commit).
        let where_ = if std::env::var("GITHUB_ACTIONS").is_ok() {
            "WARNING: this is a CI runner — the capture is discarded with the \
             checkout and the guard stays unarmed until the fixture is committed"
        } else {
            "commit it if this platform is the canonical Linux baseline"
        };
        eprintln!(
            "golden fixture {} {} — {where_}",
            path.display(),
            if update { "re-baselined (GWTF_UPDATE_GOLDEN)" } else { "did not exist; captured" }
        );
        return;
    }
    let raw = std::fs::read_to_string(&path).unwrap();
    let want = Json::parse(raw.trim()).unwrap_or_else(|e| panic!("fixture {name}: {e}"));
    assert_eq!(
        got, want,
        "golden trace '{name}' diverged from {}; if the change is intentional, \
         delete the fixture and re-run to re-baseline",
        path.display()
    );
}

#[test]
fn golden_midagg_trace_is_stable() {
    check_golden("midagg_trace", &run_mid_agg_crash(&opts()).unwrap());
}

#[test]
fn golden_jitter_trace_is_stable() {
    check_golden("jitter_trace", &run_link_jitter(&opts()).unwrap());
}
