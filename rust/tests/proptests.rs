//! Property-based tests over the coordinator's invariants (routing,
//! batching, state) using the in-repo `util::prop` helper.

use gwtf::coordinator::recovery::{plan_repair, RepairPlan};
use gwtf::cost::{edge_cost, LinkParams, NodeId, NodeProfile};
use gwtf::flow::decentralized::{DecentralizedFlow, FlowParams};
use gwtf::flow::graph::{random_problem, validate_paths, FlowProblem};
use gwtf::flow::mcmf::mcmf_min_cost;
use gwtf::util::prop::{forall, forall_res};
use gwtf::util::Rng;

fn arb_problem(rng: &mut Rng) -> (FlowProblem, u64) {
    let sources = 1 + rng.index(3);
    let stages = 2 + rng.index(6);
    let per_stage = 2 + rng.index(4);
    let relays = stages * per_stage;
    let cap_hi = 2.0 + rng.f64() * 4.0;
    let cost_hi = 5.0 + rng.f64() * 95.0;
    let seed = rng.next_u64();
    let mut prng = Rng::new(seed);
    (random_problem(sources, relays, stages, (1.0, cap_hi), (1.0, cost_hi), &mut prng), seed)
}

#[test]
fn prop_established_paths_always_valid() {
    forall_res("paths-valid", 40, arb_problem, |(prob, seed)| {
        let mut f = DecentralizedFlow::new(prob, FlowParams::default(), *seed);
        f.run(120, 8);
        validate_paths(&f.established_paths(), prob).map_err(|e| e)
    });
}

#[test]
fn prop_decentralized_never_beats_optimum() {
    // Single-source only: the exact solver handles multi-source instances
    // sequentially per commodity (the paper notes its formulation differs
    // there), which is not a valid joint lower bound.
    forall_res("cost-lower-bound", 25, arb_problem, |(prob, seed)| {
        if prob.graph.data_nodes.len() > 1 {
            return Ok(());
        }
        let params = FlowParams { minmax_objective: false, ..FlowParams::default() };
        let mut f = DecentralizedFlow::new(prob, params, *seed);
        f.run(120, 8);
        if f.complete_flows() == 0 {
            return Ok(());
        }
        let opt = mcmf_min_cost(prob);
        if f.complete_flows() == opt.flow && f.total_cost() < opt.total_cost - 1e-6 {
            return Err(format!(
                "decentralized {} beat optimal {} at equal flow {}",
                f.total_cost(),
                opt.total_cost,
                opt.flow
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_flow_capped_by_bottleneck_and_demand() {
    forall_res("flow-capped", 40, arb_problem, |(prob, seed)| {
        let mut f = DecentralizedFlow::new(prob, FlowParams::default(), *seed);
        f.run(120, 8);
        let routed = f.established_paths().len();
        let cap = prob.max_throughput();
        if routed > cap {
            return Err(format!("routed {routed} > max throughput {cap}"));
        }
        Ok(())
    });
}

#[test]
fn prop_crash_repair_preserves_validity_and_capacity() {
    forall_res("crash-repair-valid", 30, arb_problem, |(prob, seed)| {
        let mut f = DecentralizedFlow::new(prob, FlowParams::default(), *seed);
        f.run(120, 8);
        let paths = f.established_paths();
        if paths.is_empty() {
            return Ok(());
        }
        // crash every relay of the first path, one at a time
        let victims: Vec<NodeId> = paths[0].relays.clone();
        for v in victims {
            f.remove_node(v);
            validate_paths(&f.established_paths(), prob).map_err(|e| format!("after {v}: {e}"))?;
            for p in f.established_paths() {
                if p.relays.contains(&v) {
                    return Err(format!("dead node {v} still routed"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_roundstats_bitwise_deterministic() {
    // Same seed => byte-identical RoundStats traces across runs (the
    // engine's determinism guarantee at the flow-optimizer layer).
    forall_res("roundstats-deterministic", 20, arb_problem, |(prob, seed)| {
        let run = |s: u64| {
            let mut f = DecentralizedFlow::new(prob, FlowParams::default(), s);
            f.run(60, 6)
        };
        let (a, b) = (run(*seed), run(*seed));
        if a.len() != b.len() {
            return Err(format!("round counts differ: {} vs {}", a.len(), b.len()));
        }
        for (x, y) in a.iter().zip(&b) {
            if x.round != y.round
                || x.complete_flows != y.complete_flows
                || x.moves_applied != y.moves_applied
                || x.avg_cost_per_microbatch.to_bits() != y.avg_cost_per_microbatch.to_bits()
                || x.max_edge_cost.to_bits() != y.max_edge_cost.to_bits()
            {
                return Err(format!("round {} diverged: {x:?} vs {y:?}", x.round));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_engine_metrics_bitwise_deterministic() {
    // Same seed => byte-identical IterationMetrics from the
    // continuous-time engine, warm re-planning included.
    use gwtf::coordinator::GwtfRouter;
    use gwtf::sim::engine::Engine;
    use gwtf::sim::scenario::{build, ScenarioConfig};
    forall_res(
        "engine-deterministic",
        6,
        |r| (r.index(3) as f64 * 0.1, r.next_u64()),
        |&(churn_p, seed)| {
            let run = || {
                let sc = build(&ScenarioConfig::table2(false, churn_p, seed));
                let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), seed);
                let mut engine = Engine::from_scenario(&sc, seed ^ 1);
                engine.warm_replan = true;
                (0..3)
                    .map(|_| engine.step(&sc.prob, &mut router))
                    .map(|m| {
                        (
                            m.completed,
                            m.dropped,
                            m.fwd_recoveries,
                            m.bwd_recoveries,
                            m.makespan_s.to_bits(),
                            m.comm_s.to_bits(),
                            m.wasted_gpu_s.to_bits(),
                            m.agg_s.to_bits(),
                        )
                    })
                    .collect::<Vec<_>>()
            };
            let (a, b) = (run(), run());
            if a != b {
                return Err(format!("engine metrics diverged:\n{a:?}\nvs\n{b:?}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_warm_replan_flows_valid() {
    // Warm-start re-planning after crashes must only emit valid flows:
    // stage-correct, within capacity, and never through a dead node.
    forall_res("warm-replan-valid", 25, arb_problem, |(prob, seed)| {
        let mut cold = DecentralizedFlow::new(prob, FlowParams::default(), *seed);
        cold.run(80, 6);
        if cold.complete_flows() == 0 {
            return Ok(());
        }
        let chains = cold.chains.clone();
        let temp = cold.temperature();
        // kill ~20% of the relays
        let mut rng = Rng::new(*seed ^ 0xAB);
        let victims: Vec<NodeId> = prob
            .graph
            .stages
            .iter()
            .flatten()
            .filter(|_| rng.chance(0.2))
            .copied()
            .collect();
        let mut warm =
            DecentralizedFlow::warm_start(prob, FlowParams::default(), *seed ^ 2, chains, temp);
        for &v in &victims {
            warm.remove_node(v);
        }
        warm.run(40, 4);
        let paths = warm.established_paths();
        validate_paths(&paths, prob).map_err(|e| format!("invalid after warm replan: {e}"))?;
        for p in &paths {
            for r in &p.relays {
                if victims.contains(r) {
                    return Err(format!("dead node {r} still routed"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_mcmf_flow_conservation() {
    // every decomposed path visits each stage exactly once, source == sink
    forall_res("mcmf-paths", 30, arb_problem, |(prob, _)| {
        let sol = mcmf_min_cost(prob);
        if sol.paths.len() != sol.flow {
            return Err(format!("{} paths for flow {}", sol.paths.len(), sol.flow));
        }
        validate_paths(&sol.paths, prob).map_err(|e| e)?;
        // total cost equals sum of path costs
        let sum: f64 = sol.paths.iter().map(|p| p.cost(prob)).sum();
        if (sum - sol.total_cost).abs() > 1e-6 * sum.abs().max(1.0) {
            return Err(format!("cost mismatch: paths {sum} vs reported {}", sol.total_cost));
        }
        Ok(())
    });
}

#[test]
fn prop_eq1_cost_positive_and_monotone_in_size() {
    forall("eq1-monotone", 200, |r| {
        (
            NodeProfile::new(r.uniform(0.1, 10.0), 1 + r.index(4)),
            NodeProfile::new(r.uniform(0.1, 10.0), 1 + r.index(4)),
            LinkParams::new(r.uniform(0.001, 0.3), r.uniform(1e6, 1e9)),
            LinkParams::new(r.uniform(0.001, 0.3), r.uniform(1e6, 1e9)),
            r.uniform(1e3, 1e9),
        )
    }, |(a, b, ij, ji, size)| {
        let c = edge_cost(a, b, ij, ji, *size);
        let c2 = edge_cost(a, b, ij, ji, *size * 2.0);
        c > 0.0 && c2 >= c && edge_cost(b, a, ji, ij, *size) == c
    });
}

#[test]
fn prop_repair_plan_never_reuses_dead_nodes() {
    forall_res("repair-no-dead", 40, arb_problem, |(prob, seed)| {
        let mut rng = Rng::new(*seed);
        // build one straight path through the stages
        let relays: Vec<NodeId> =
            prob.graph.stages.iter().map(|s| s[rng.index(s.len())]).collect();
        let path = gwtf::flow::graph::FlowPath { source: prob.graph.data_nodes[0], relays };
        // kill a random subset of its relays
        let dead: Vec<NodeId> =
            path.relays.iter().filter(|_| rng.chance(0.4)).copied().collect();
        if dead.is_empty() {
            return Ok(());
        }
        let plan = plan_repair(
            &path,
            &prob.graph,
            |n| !dead.contains(&n),
            |_| true,
            |i, j| prob.cost(i, j),
        );
        match plan {
            RepairPlan::Repaired { path: p, .. } => {
                for d in &dead {
                    if p.relays.contains(d) {
                        return Err(format!("dead {d} reused"));
                    }
                }
                Ok(())
            }
            RepairPlan::Unrecoverable { failed_stage, .. } => {
                // unrecoverable only if that stage truly has no live spare
                let any_alive = prob.graph.stages[failed_stage]
                    .iter()
                    .any(|n| !dead.contains(n) && *n != path.relays[failed_stage]);
                if any_alive {
                    Err(format!("gave up at stage {failed_stage} despite live spare"))
                } else {
                    Ok(())
                }
            }
            RepairPlan::Intact => Err("dead nodes but plan says intact".into()),
        }
    });
}

#[test]
fn prop_churn_process_liveness_consistent() {
    forall_res("churn-liveness", 50, |r| (r.index(40) + 2, r.f64() * 0.5, r.next_u64()), |&(n, p, seed)| {
        let relays: Vec<NodeId> = (0..n).map(NodeId).collect();
        let mut c = gwtf::sim::ChurnProcess::new(n, relays, p, seed);
        for _ in 0..20 {
            let ev = c.sample_iteration();
            for (node, frac) in &ev.crashes {
                if c.is_alive(*node) {
                    return Err(format!("{node} crashed but still alive"));
                }
                if !(0.0..1.0).contains(frac) {
                    return Err(format!("bad crash fraction {frac}"));
                }
            }
            for node in &ev.rejoins {
                if !c.is_alive(*node) {
                    return Err(format!("{node} rejoined but still dead"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_poisson_arrivals_increasing_finite_alternating() {
    // Continuous-clock churn invariants: per-relay arrival times are
    // strictly increasing, finite, non-NaN, with fractions in [0, 1), and
    // transitions alternate crash/rejoin starting from alive.
    use gwtf::sim::churn_process::PoissonChurn;
    forall_res(
        "poisson-arrivals",
        30,
        |r| (1 + r.index(12), 0.05 + r.f64() * 1.5, r.next_u64()),
        |&(n, rate, seed)| {
            let relays: Vec<NodeId> = (0..n).map(NodeId).collect();
            let mut pc = PoissonChurn::new(relays, rate, seed);
            let mut last = vec![f64::NEG_INFINITY; n];
            let mut expect_crash = vec![true; n];
            for iter in 0..40 {
                for tr in pc.advance_iteration() {
                    let i = tr.node.0;
                    if tr.at.is_nan() || !tr.at.is_finite() {
                        return Err(format!("non-finite arrival fraction {}", tr.at));
                    }
                    if !(0.0..1.0).contains(&tr.at) {
                        return Err(format!("fraction {} outside [0,1)", tr.at));
                    }
                    let t = iter as f64 + tr.at;
                    if t <= last[i] {
                        return Err(format!("arrivals not strictly increasing: {t} <= {}", last[i]));
                    }
                    last[i] = t;
                    if tr.crash != expect_crash[i] {
                        return Err(format!(
                            "liveness alternation violated at {t}: expected crash={}",
                            expect_crash[i]
                        ));
                    }
                    expect_crash[i] = !expect_crash[i];
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_poisson_schedule_respects_node_liveness() {
    // Through the engine-facing EventSource view: no crash of an
    // already-dead node, no rejoin/join of an alive one.
    use gwtf::sim::{ChurnModel, ChurnProcess, EventSource};
    forall_res(
        "poisson-liveness",
        30,
        |r| (2 + r.index(14), 0.1 + r.f64() * 1.2, r.next_u64()),
        |&(n, p, seed)| {
            let relays: Vec<NodeId> = (0..n).map(NodeId).collect();
            let mut c = ChurnProcess::with_model(ChurnModel::Poisson, n, relays, p, seed);
            for iter in 0..30 {
                let before = c.alive.clone();
                let sched = EventSource::sample(&mut c, iter, 120.0);
                if !sched.rejoins.is_empty() {
                    return Err("poisson churn must emit timestamped joins, not rejoins".into());
                }
                for &(node, t) in &sched.crashes {
                    if !before[node.0] {
                        return Err(format!("{node} crashed but was already dead"));
                    }
                    if !t.is_finite() || !(0.0..120.0).contains(&t) {
                        return Err(format!("bad crash time {t}"));
                    }
                }
                for &(node, t) in &sched.joins {
                    if before[node.0] {
                        return Err(format!("{node} joined but was already alive"));
                    }
                    if !t.is_finite() || !(0.0..120.0).contains(&t) {
                        return Err(format!("bad join time {t}"));
                    }
                    c.alive[node.0] = true; // what the engine does post-iteration
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_poisson_stream_bitwise_deterministic() {
    use gwtf::sim::churn_process::PoissonChurn;
    forall_res(
        "poisson-deterministic",
        20,
        |r| (1 + r.index(10), 0.05 + r.f64(), r.next_u64()),
        |&(n, rate, seed)| {
            let relays: Vec<NodeId> = (0..n).map(NodeId).collect();
            let mut a = PoissonChurn::new(relays.clone(), rate, seed);
            let mut b = PoissonChurn::new(relays, rate, seed);
            for iter in 0..25 {
                let (ea, eb) = (a.advance_iteration(), b.advance_iteration());
                if ea.len() != eb.len() {
                    return Err(format!("iteration {iter}: {} vs {} events", ea.len(), eb.len()));
                }
                for (x, y) in ea.iter().zip(&eb) {
                    if x.node != y.node || x.crash != y.crash || x.at.to_bits() != y.at.to_bits()
                    {
                        return Err(format!("iteration {iter} diverged: {x:?} vs {y:?}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_leader_placement_total_and_in_range() {
    use gwtf::coordinator::join::{JoinPolicy, Leader, StageUtilization};
    forall_res("placement-total", 50, |r| {
        let n_stages = 2 + r.index(10);
        let n_cands = 1 + r.index(20);
        let caps: Vec<usize> = (0..n_cands).map(|_| 1 + r.index(20)).collect();
        let util: Vec<StageUtilization> = (0..n_stages)
            .map(|s| StageUtilization { stage: s, capacity: 1 + r.index(30), flows: r.index(30) })
            .collect();
        let policy = match r.index(3) {
            0 => JoinPolicy::UtilizationRanked,
            1 => JoinPolicy::CapacityFirst,
            _ => JoinPolicy::Random,
        };
        (caps, util, policy, r.next_u64())
    }, |(caps, util, policy, seed)| {
        let mut leader = Leader::new(NodeId(0), *policy);
        for (i, &c) in caps.iter().enumerate() {
            leader.on_join_request(NodeId(1000 + i), c);
        }
        let mut rng = Rng::new(*seed);
        // UtilizationRanked places at most one candidate per stage per
        // round (the leader is periodic); keep calling until drained.
        let mut placed = Vec::new();
        let mut rounds = 0;
        while !leader.candidates.is_empty() {
            let batch = leader.place(util, &mut rng);
            if batch.is_empty() {
                return Err("placement round made no progress".into());
            }
            placed.extend(batch);
            rounds += 1;
            if rounds > caps.len() + 1 {
                return Err("too many placement rounds".into());
            }
        }
        if placed.len() != caps.len() {
            return Err(format!("placed {} of {}", placed.len(), caps.len()));
        }
        for (_, s) in &placed {
            if *s >= util.len() {
                return Err(format!("stage {s} out of range"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_nic_transmissions_never_exceed_concurrency() {
    // Shared-capacity substrate invariant (ISSUE 5): however transfers
    // arrive, no NIC direction ever carries more concurrent
    // transmissions than its class cap, and no transfer starts before
    // its payload is ready.
    use gwtf::cost::NicConfig;
    use gwtf::sim::NicQueues;

    type Case = (Vec<usize>, NicConfig, Vec<(usize, usize, f64, f64)>);
    fn arb_case(rng: &mut Rng) -> Case {
        let n = 3 + rng.index(4);
        let region: Vec<usize> = (0..n).map(|_| rng.index(3)).collect();
        let nic = NicConfig {
            wan_concurrency: Some(1 + rng.index(3)),
            lan_concurrency: if rng.chance(0.3) { None } else { Some(1 + rng.index(4)) },
        };
        let transfers: Vec<(usize, usize, f64, f64)> = (0..30)
            .map(|_| {
                let from = rng.index(n);
                let mut to = rng.index(n);
                if to == from {
                    to = (to + 1) % n;
                }
                (from, to, rng.uniform(0.0, 50.0), rng.uniform(0.1, 20.0))
            })
            .collect();
        (region, nic, transfers)
    }

    forall_res("nic-cap-invariant", 40, arb_case, |(region, nic, transfers)| {
        let mut nq = NicQueues::new(*nic, region.clone());
        // (node, is_up, same_region, start, end) per booked transmission
        let mut booked: Vec<(usize, bool, bool, f64, f64)> = Vec::new();
        for &(from, to, ready, tx) in transfers {
            let same = region[from] == region[to];
            let start = nq.acquire(NodeId(from), NodeId(to), ready, tx);
            if start < ready - 1e-9 {
                return Err(format!("transfer started before ready: {start} < {ready}"));
            }
            if nic.cap(same).is_some() {
                booked.push((from, true, same, start, start + tx));
                booked.push((to, false, same, start, start + tx));
            }
        }
        // At every transmission start, the overlapping count per NIC
        // (node, direction, class) must respect the class cap (same
        // overlap semantics as Slots: a booking occupies [start, end)
        // with a 1e-9 guard).
        for &(node, up, same, s, _) in &booked {
            let cap = nic.cap(same).expect("only capped classes are booked");
            let concurrent = booked
                .iter()
                .filter(|&&(n2, up2, same2, s2, e2)| {
                    n2 == node && up2 == up && same2 == same && s2 <= s + 1e-9 && e2 > s + 1e-9
                })
                .count();
            if concurrent > cap {
                return Err(format!(
                    "NIC (node {node}, up {up}, lan {same}) carried {concurrent} > cap {cap} at t={s}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_nic_unlimited_is_identity_and_ample_caps_never_queue() {
    // Unlimited mode returns the ready instant untouched; finite caps at
    // least as large as the transfer count behave identically (nothing
    // ever queues) — the degenerate-substrate guarantee behind the
    // engine-level bit-for-bit parity tests.
    use gwtf::cost::NicConfig;
    use gwtf::sim::NicQueues;

    forall_res(
        "nic-ample-identity",
        30,
        |rng: &mut Rng| {
            let n = 2 + rng.index(4);
            let region: Vec<usize> = (0..n).map(|_| rng.index(2)).collect();
            let transfers: Vec<(usize, usize, f64, f64)> = (0..16)
                .map(|_| {
                    let from = rng.index(n);
                    let mut to = rng.index(n);
                    if to == from {
                        to = (to + 1) % n;
                    }
                    (from, to, rng.uniform(0.0, 10.0), rng.uniform(0.1, 5.0))
                })
                .collect();
            (region, transfers)
        },
        |(region, transfers)| {
            let mut unlimited = NicQueues::new(NicConfig::UNLIMITED, region.clone());
            let mut ample = NicQueues::new(NicConfig::uniform(64), region.clone());
            for &(from, to, ready, tx) in transfers {
                let a = unlimited.acquire(NodeId(from), NodeId(to), ready, tx);
                let b = ample.acquire(NodeId(from), NodeId(to), ready, tx);
                if a.to_bits() != ready.to_bits() {
                    return Err(format!("unlimited acquire moved the clock: {a} vs {ready}"));
                }
                if b.to_bits() != a.to_bits() {
                    return Err(format!("ample caps queued where unlimited did not: {b} vs {a}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_reputation_scores_stay_in_unit_interval() {
    // Arbitrary interleavings of deny/service/delivery observations and
    // publishes must keep every score inside [0, 1] — the EWMA folds
    // clamped means through a clamped update, so no sample sequence can
    // push a score out of the unit interval (and the Eq. 1 penalty
    // stays within [1, 1 + 2w]).
    use gwtf::net::{ReputationBook, REP_ALPHA, REP_PENALTY_WEIGHT};

    forall_res(
        "reputation-unit-interval",
        40,
        |rng: &mut Rng| {
            let n = 2 + rng.index(6);
            let ops: Vec<(usize, u8, f64, f64)> = (0..64)
                .map(|_| {
                    (
                        rng.index(n),
                        (rng.index(4)) as u8,
                        rng.uniform(0.0, 100.0),
                        rng.uniform(0.01, 100.0),
                    )
                })
                .collect();
            (n, ops)
        },
        |(n, ops)| {
            let book = ReputationBook::new(*n, REP_ALPHA, REP_PENALTY_WEIGHT);
            for (step, &(node, op, a, b)) in ops.iter().enumerate() {
                let node = NodeId(node);
                match op {
                    0 => book.observe_deny(node),
                    1 => book.observe_service(node, a, b),
                    2 => book.observe_delivery(node),
                    _ => book.publish(step as f64),
                }
                for i in 0..*n {
                    let s = book.score(NodeId(i));
                    if !(0.0..=1.0).contains(&s) {
                        return Err(format!("score[{i}] = {s} left [0,1] at step {step}"));
                    }
                    for j in 0..*n {
                        let p = book.penalty(NodeId(i), NodeId(j));
                        if p < 1.0 || p > 1.0 + 2.0 * REP_PENALTY_WEIGHT {
                            return Err(format!("penalty({i},{j}) = {p} out of range"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_procedural_links_match_materialized_keyed_bits() {
    // Sparse-substrate parity (ISSUE 10): the recompute-on-demand
    // Procedural arm and the MaterializedKeyed dense matrix must agree
    // bitwise on every directed pair at 100 and 200 relays, for any
    // seed, and leave the shared generator on the same stream (so every
    // downstream draw — churn, profiles — is arm-independent).
    use gwtf::net::{LinkGen, Topology, TopologyConfig};
    forall_res(
        "procedural-link-parity",
        10,
        |r| (if r.chance(0.5) { 100 } else { 200 }, r.next_u64()),
        |&(n, seed)| {
            let cfg = |link_gen| TopologyConfig {
                n_nodes: n,
                link_gen,
                ..TopologyConfig::default()
            };
            let mut rng_m = Rng::new(seed);
            let mut rng_p = Rng::new(seed);
            let tm = Topology::generate(&cfg(LinkGen::MaterializedKeyed), &mut rng_m);
            let tp = Topology::generate(&cfg(LinkGen::Procedural), &mut rng_p);
            if tm.is_procedural() || !tp.is_procedural() {
                return Err("arms landed on the wrong stores".into());
            }
            if tm.region != tp.region {
                return Err("region assignment diverged between keyed arms".into());
            }
            for i in 0..n {
                for j in 0..n {
                    let (a, b) = (tm.link(i, j), tp.link(i, j));
                    if a.latency_s.to_bits() != b.latency_s.to_bits()
                        || a.bandwidth_bps.to_bits() != b.bandwidth_bps.to_bits()
                    {
                        return Err(format!("link {i}->{j} diverged: {a:?} vs {b:?}"));
                    }
                }
            }
            if rng_m.next_u64() != rng_p.next_u64() {
                return Err("keyed arms consumed different generator draws".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_engine_metrics_bit_identical_under_keyed_link_arms() {
    // End-to-end arm transparency (ISSUE 10): a scale scenario run under
    // MaterializedKeyed and under Procedural — same seed, same churn —
    // must produce bitwise-identical engine metrics: the only difference
    // between the arms is *where* link params live, never what they are
    // or what anything downstream draws.
    use gwtf::coordinator::GwtfRouter;
    use gwtf::net::LinkGen;
    use gwtf::sim::scenario::{build, ScenarioConfig};
    forall_res(
        "keyed-arm-engine-parity",
        4,
        |r| (if r.chance(0.5) { 100 } else { 200 }, r.next_u64()),
        |&(n, seed)| {
            let run = |link_gen| {
                let mut cfg = ScenarioConfig::scale(n, 0.2, seed);
                cfg.link_gen = link_gen;
                let sc = build(&cfg);
                let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), seed ^ 0xA);
                let mut engine = sc.engine(seed ^ 0x1);
                engine.warm_replan = true;
                (0..2)
                    .map(|_| engine.step(&sc.prob, &mut router))
                    .map(|m| {
                        (
                            m.completed,
                            m.dropped,
                            m.events,
                            m.makespan_s.to_bits(),
                            m.comm_s.to_bits(),
                            m.agg_s.to_bits(),
                        )
                    })
                    .collect::<Vec<_>>()
            };
            let a = run(LinkGen::MaterializedKeyed);
            let b = run(LinkGen::Procedural);
            if a != b {
                return Err(format!(
                    "engine metrics diverged between keyed arms at n={n}:\n{a:?}\nvs\n{b:?}"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_reputation_convergence_is_deterministic_per_seed() {
    // Two books fed the identical observation sequence agree bitwise
    // after every publish — the property that makes the adversary sweep
    // reproducible per seed (no wall clock, no map iteration order, no
    // atomics-race sensitivity in the single-threaded engine).
    use gwtf::net::{ReputationBook, REP_ALPHA, REP_PENALTY_WEIGHT};

    forall_res(
        "reputation-deterministic",
        30,
        |rng: &mut Rng| {
            let n = 2 + rng.index(6);
            let ops: Vec<(usize, u8, f64, f64)> = (0..96)
                .map(|_| {
                    (
                        rng.index(n),
                        (rng.index(4)) as u8,
                        rng.uniform(0.0, 100.0),
                        rng.uniform(0.01, 100.0),
                    )
                })
                .collect();
            (n, ops)
        },
        |(n, ops)| {
            let a = ReputationBook::new(*n, REP_ALPHA, REP_PENALTY_WEIGHT);
            let b = ReputationBook::new(*n, REP_ALPHA, REP_PENALTY_WEIGHT);
            for (step, &(node, op, x, y)) in ops.iter().enumerate() {
                let node = NodeId(node);
                for book in [&a, &b] {
                    match op {
                        0 => book.observe_deny(node),
                        1 => book.observe_service(node, x, y),
                        2 => book.observe_delivery(node),
                        _ => book.publish(step as f64),
                    }
                }
                for i in 0..*n {
                    let (sa, sb) = (a.score(NodeId(i)), b.score(NodeId(i)));
                    if sa.to_bits() != sb.to_bits() {
                        return Err(format!(
                            "score[{i}] diverged at step {step}: {sa} vs {sb}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}
