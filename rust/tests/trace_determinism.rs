//! Tracing acceptance gates (ISSUE 8).
//!
//! Three properties keep the flight recorder trustworthy:
//!
//! - **Zero overhead when disarmed**: running the engine with a sink
//!   armed must not move a single bit of any metric — tracing is
//!   strictly observational (no RNG draw, no time mutation), so armed
//!   and disarmed runs are bit-for-bit identical.
//! - **Determinism per seed**: with a sink armed, the record stream is
//!   a pure function of the run — two identical runs produce identical
//!   streams, record for record.
//! - **Critical-path attribution is exhaustive**: the per-iteration
//!   `crit_path` buckets tile the makespan — they sum to `makespan_s`
//!   within 1e-6 relative on every scenario family (mid-aggregation
//!   crashes, link jitter, NIC congestion, bounded staleness).
//!
//! Plus a shape gate on the Chrome exporter: a real engine stream must
//! render as valid trace-event objects with monotone per-track
//! timestamps.
//!
//! CI runs this test in the same release guard step as the bench gates.

use gwtf::coordinator::GwtfRouter;
use gwtf::flow::FlowParams;
use gwtf::sim::scenario::{build, Scenario, ScenarioConfig};
use gwtf::sim::sources::{LinkJitterSource, MidAggCrashSource};
use gwtf::sim::training::IterationMetrics;
use gwtf::sim::Engine;
use gwtf::trace::{arm_collector, chrome, TraceRecord};
use gwtf::util::json::Json;

const ARMS: [&str; 4] = ["midagg", "jitter", "congestion", "async"];
const ITERS: usize = 3;
const SEED: u64 = 7;

/// Run one named scenario arm for [`ITERS`] iterations.  Mirrors the
/// constructions in `experiments/scenarios.rs` so the gates cover the
/// event kinds each family exercises (barrier crashes, jitter windows,
/// NIC queueing, rolling aggregation + admission catch-up).
fn run_arm(arm: &str) -> Vec<IterationMetrics> {
    type Hook = Box<dyn FnOnce(&mut Engine)>;
    let (sc, hook, warm): (Scenario, Option<Hook>, bool) = match arm {
        "midagg" => {
            let sc = build(&ScenarioConfig::table2(true, 0.0, SEED));
            let last_stage = sc.prob.graph.n_stages() - 1;
            let victim = sc.prob.graph.stages[last_stage][0];
            let hook: Hook = Box::new(move |e| {
                e.add_source(Box::new(MidAggCrashSource::new(1, victim, 0.5)));
            });
            (sc, Some(hook), true)
        }
        "jitter" => {
            let sc = build(&ScenarioConfig::table2(true, 0.0, SEED));
            let hook: Hook = Box::new(|e| {
                e.add_source(Box::new(LinkJitterSource::new(0.5, 30.0, SEED ^ 0x11)));
            });
            (sc, Some(hook), false)
        }
        "congestion" => (build(&ScenarioConfig::congestion(Some(1), true, SEED)), None, false),
        "async" => (build(&ScenarioConfig::bounded_staleness(Some(2), 0.2, SEED)), None, true),
        other => unreachable!("unknown arm {other}"),
    };
    let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), SEED ^ 0xA);
    let mut engine = sc.engine(SEED ^ 0x1);
    engine.warm_replan = warm;
    if let Some(hook) = hook {
        hook(&mut engine);
    }
    (0..ITERS).map(|_| engine.step(&sc.prob, &mut router)).collect()
}

/// Record stream of one armed run.
fn stream(arm: &str) -> Vec<TraceRecord> {
    let (guard, recs) = arm_collector();
    let _metrics = run_arm(arm);
    drop(guard);
    let out = recs.borrow().clone();
    out
}

#[test]
fn record_stream_is_deterministic_per_seed() {
    for arm in ARMS {
        let a = stream(arm);
        let b = stream(arm);
        assert!(!a.is_empty(), "{arm}: an instrumented run must emit records");
        assert_eq!(a.len(), b.len(), "{arm}: stream lengths diverged");
        for (i, (ra, rb)) in a.iter().zip(&b).enumerate() {
            assert_eq!(ra, rb, "{arm}: record {i} diverged between identical runs");
        }
    }
}

#[test]
fn armed_sink_never_moves_a_metric_bit() {
    for arm in ARMS {
        let plain = run_arm(arm);
        let (guard, recs) = arm_collector();
        let traced = run_arm(arm);
        drop(guard);
        assert!(!recs.borrow().is_empty(), "{arm}: sink saw no records");
        for (i, (p, t)) in plain.iter().zip(&traced).enumerate() {
            let pairs = [
                ("makespan_s", p.makespan_s, t.makespan_s),
                ("comm_s", p.comm_s, t.comm_s),
                ("queue_s", p.queue_s, t.queue_s),
                ("agg_s", p.agg_s, t.agg_s),
                ("planning_s", p.planning_s, t.planning_s),
                ("plan_overlap_s", p.plan_overlap_s, t.plan_overlap_s),
                ("wasted_gpu_s", p.wasted_gpu_s, t.wasted_gpu_s),
                ("staleness_mean", p.staleness_mean, t.staleness_mean),
                ("crit.compute_s", p.crit_path.compute_s, t.crit_path.compute_s),
                ("crit.tx_s", p.crit_path.tx_s, t.crit_path.tx_s),
                ("crit.prop_s", p.crit_path.prop_s, t.crit_path.prop_s),
                ("crit.queue_s", p.crit_path.queue_s, t.crit_path.queue_s),
                ("crit.plan_s", p.crit_path.plan_s, t.crit_path.plan_s),
                ("crit.agg_s", p.crit_path.agg_s, t.crit_path.agg_s),
                ("crit.stale_s", p.crit_path.stale_s, t.crit_path.stale_s),
            ];
            for (name, a, b) in pairs {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{arm} iter {i}: {name} moved under tracing ({a} vs {b})"
                );
            }
            assert_eq!(p.completed, t.completed, "{arm} iter {i}");
            assert_eq!(p.events, t.events, "{arm} iter {i}");
            assert_eq!(p.fwd_recoveries, t.fwd_recoveries, "{arm} iter {i}");
            assert_eq!(p.bwd_recoveries, t.bwd_recoveries, "{arm} iter {i}");
            assert_eq!(p.dropped, t.dropped, "{arm} iter {i}");
        }
    }
}

#[test]
fn critical_path_buckets_sum_to_makespan() {
    for arm in ARMS {
        let mut attributed = false;
        for (i, m) in run_arm(arm).iter().enumerate() {
            let sum = m.crit_path.total_s();
            let err = (sum - m.makespan_s).abs();
            assert!(
                err <= 1e-6 * m.makespan_s.abs().max(1.0),
                "{arm} iter {i}: buckets sum to {sum}, makespan is {} \
                 (compute {} tx {} prop {} queue {} plan {} agg {} stale {})",
                m.makespan_s,
                m.crit_path.compute_s,
                m.crit_path.tx_s,
                m.crit_path.prop_s,
                m.crit_path.queue_s,
                m.crit_path.plan_s,
                m.crit_path.agg_s,
                m.crit_path.stale_s,
            );
            if m.makespan_s > 0.0 {
                attributed = true;
                assert!(m.crit_path.compute_s > 0.0, "{arm} iter {i}: no compute attributed");
            }
        }
        assert!(attributed, "{arm}: every iteration had zero makespan");
    }
}

#[test]
fn chrome_export_of_a_real_stream_is_well_shaped() {
    let recs = stream("congestion");
    let doc = chrome::chrome_trace_json(&recs);
    let events = doc.get("traceEvents").expect("traceEvents array").as_arr().unwrap();
    assert_eq!(events.len(), recs.len(), "every record exports exactly one event");
    let key = |ev: &Json| {
        (
            ev.get("pid").unwrap().as_usize().unwrap(),
            ev.get("tid").unwrap().as_usize().unwrap(),
        )
    };
    for ev in events {
        assert!(ev.get("name").unwrap().as_str().is_some());
        let ph = ev.get("ph").unwrap().as_str().unwrap();
        assert!(ph == "X" || ph == "i", "unknown phase {ph:?}");
        let ts = ev.get("ts").unwrap().as_f64().unwrap();
        assert!(ts.is_finite() && ts >= 0.0);
        if ph == "X" {
            assert!(ev.get("dur").unwrap().as_f64().unwrap() > 0.0);
        }
    }
    for w in events.windows(2) {
        if key(&w[0]) == key(&w[1]) {
            let (a, b) = (
                w[0].get("ts").unwrap().as_f64().unwrap(),
                w[1].get("ts").unwrap().as_f64().unwrap(),
            );
            assert!(a <= b, "per-track timestamps must be monotone: {a} > {b}");
        }
    }
    // The full document survives serialize -> parse.
    let text = doc.to_string();
    assert_eq!(Json::parse(&text).unwrap(), doc);

    // And the file writer produces the same document on disk.
    let dir = std::env::temp_dir().join("gwtf_trace_export_test");
    let path = dir.join("trace.json");
    let _ = std::fs::remove_file(&path);
    chrome::write_chrome_trace(&path, &recs).unwrap();
    let back = Json::parse(std::fs::read_to_string(&path).unwrap().trim()).unwrap();
    assert_eq!(back, doc);
}
