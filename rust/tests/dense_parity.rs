//! Thread-count invariance of the dense-state planner (ISSUE 6).
//!
//! The planner's parallel candidate evaluation precomputes pure f64
//! cost matrices on worker threads and replays every decision
//! sequentially on the main thread with the main RNG, so the thread
//! count must never move a single bit: same plans, same protocol
//! rounds, same per-round scan counters, same engine metrics.  These
//! tests pin that contract on the gossip-overlay scale scenario at 100
//! relays (the ISSUE 3 acceptance shape) and at 200 relays, where the
//! Request Redirect cost matrix crosses the parallel-dispatch
//! threshold and the worker threads genuinely engage.

use gwtf::coordinator::GwtfRouter;
use gwtf::flow::decentralized::DecentralizedFlow;
use gwtf::flow::graph::FlowPath;
use gwtf::flow::FlowParams;
use gwtf::net::{GossipConfig, Overlay};
use gwtf::sim::scenario::{build, ScenarioConfig};

const THREADS: [usize; 2] = [1, 4];

fn params(threads: usize) -> FlowParams {
    FlowParams { threads, ..FlowParams::default() }
}

/// Per-round planner trace: every deterministic counter plus the cost
/// bits.
fn planner_trace(relays: usize, threads: usize) -> Vec<(usize, usize, usize, usize, u64)> {
    let sc = build(&ScenarioConfig::scale(relays, 0.2, 11));
    let alive = vec![true; sc.topo.n()];
    let mut ov = Overlay::build(&sc.prob.graph, sc.topo.n(), GossipConfig::default(), 11);
    ov.reconcile(&alive);
    let mut flow = DecentralizedFlow::new(&sc.prob, params(threads), 19);
    flow.set_neighbors(ov.neighbor_map());
    flow.run(40, 8)
        .iter()
        .map(|s| {
            (
                s.moves_applied,
                s.candidate_scans,
                s.change_scans,
                s.complete_flows,
                s.avg_cost_per_microbatch.to_bits(),
            )
        })
        .collect()
}

#[test]
fn planner_round_trace_is_thread_count_invariant() {
    for &relays in &[100usize, 200] {
        let base = planner_trace(relays, THREADS[0]);
        assert!(!base.is_empty(), "{relays}-relay plan ran no rounds");
        let threaded = planner_trace(relays, THREADS[1]);
        assert_eq!(
            base, threaded,
            "{relays} relays: planner trace diverged between 1 and 4 threads"
        );
    }
}

/// Cold plan + warm re-plan through the router: paths and rounds.
fn router_plans(relays: usize, threads: usize) -> (Vec<FlowPath>, usize, Vec<FlowPath>, usize) {
    let sc = build(&ScenarioConfig::scale(relays, 0.2, 13));
    let mut r = GwtfRouter::from_scenario(&sc, params(threads), 13 ^ 0xA);
    let mut alive = vec![true; sc.topo.n()];
    let (cold, _) = r.plan(&alive);
    let cold_rounds = r.last_rounds;
    let victim = cold[0].relays[1];
    alive[victim.0] = false;
    let (warm, _) = r.replan(&alive, &[victim]);
    (cold, cold_rounds, warm, r.last_rounds)
}

#[test]
fn router_plans_are_thread_count_invariant() {
    for &relays in &[100usize, 200] {
        let a = router_plans(relays, THREADS[0]);
        let b = router_plans(relays, THREADS[1]);
        assert_eq!(a.0, b.0, "{relays} relays: cold plans diverged");
        assert_eq!(a.1, b.1, "{relays} relays: cold rounds diverged");
        assert_eq!(a.2, b.2, "{relays} relays: warm re-plans diverged");
        assert_eq!(a.3, b.3, "{relays} relays: warm rounds diverged");
    }
}

/// Full engine iterations: metric bits and event counts.
fn engine_trace(relays: usize, threads: usize) -> Vec<(usize, usize, u64, u64, usize, usize)> {
    let sc = build(&ScenarioConfig::scale(relays, 0.2, 17));
    let mut router = GwtfRouter::from_scenario(&sc, params(threads), 17 ^ 0xA);
    let mut engine = sc.engine(17 ^ 0x1);
    engine.warm_replan = true;
    (0..3)
        .map(|_| {
            let m = engine.step(&sc.prob, &mut router);
            (
                m.completed,
                m.dropped,
                m.makespan_s.to_bits(),
                m.comm_s.to_bits(),
                m.replan_rounds,
                m.events,
            )
        })
        .collect()
}

#[test]
fn engine_metrics_are_thread_count_invariant() {
    for &relays in &[100usize, 200] {
        let base = engine_trace(relays, THREADS[0]);
        assert!(base.iter().any(|r| r.0 > 0), "{relays}-relay engine completed nothing");
        let threaded = engine_trace(relays, THREADS[1]);
        assert_eq!(
            base, threaded,
            "{relays} relays: engine metrics diverged between 1 and 4 threads"
        );
    }
}
