//! Shared conformance suite for the [`RoutingPolicy`] contract (ISSUE 4
//! satellite): one generic body run against GWTF, SWARM and DT-FM, so
//! the contract's invariants are asserted once instead of re-implemented
//! ad hoc per router:
//!
//! - **plan validity** — every committed plan routes stage-valid paths
//!   sourced at data nodes, within the per-source demand;
//! - **dead-node exclusion** — a node dead at request time never appears
//!   in the committed paths, and `choose_replacement` only ever picks
//!   from the offered candidates;
//! - **determinism per seed** — same seed, same request sequence =>
//!   identical paths;
//! - **ticket/commit ordering** — ticket ids strictly increase, the
//!   request's `dirty` set seeds the ticket's invalidation set, and a
//!   commit with no mid-flight invalidation is clean (`stale == false`,
//!   blocking claim `committed_at == requested_at + ready_after_s`).

use gwtf::baselines::{DtfmRouter, GaParams, SwarmRouter};
use gwtf::coordinator::GwtfRouter;
use gwtf::cost::NodeId;
use gwtf::flow::graph::FlowPath;
use gwtf::flow::FlowParams;
use gwtf::sim::scenario::{build, Scenario, ScenarioConfig};
use gwtf::sim::training::{BlockingPlanAdapter, PlanRequest, RoutingPolicy};
use std::sync::Arc;

fn scenario(seed: u64) -> Scenario {
    build(&ScenarioConfig::table2(true, 0.0, seed))
}

fn request_commit<R: RoutingPolicy>(
    r: &mut R,
    alive: &[bool],
    dirty: &[NodeId],
    warm: bool,
) -> (gwtf::sim::training::PlanTicket, gwtf::sim::training::PlanOutcome) {
    let req = PlanRequest { alive, dirty, warm, requested_at: 0.0, iter: 0 };
    let ticket = r.request_plan(&req);
    let out = r.commit_plan(&ticket, &[]);
    (ticket, out)
}

fn assert_plan_valid(sc: &Scenario, paths: &[FlowPath], alive: &[bool], label: &str) {
    assert!(!paths.is_empty(), "{label}: empty plan with everyone alive");
    let total_demand: usize = sc.prob.demand.iter().sum();
    assert!(paths.len() <= total_demand, "{label}: routed more than the demand");
    for p in paths {
        assert!(sc.prob.graph.is_data_node(p.source), "{label}: source not a data node");
        assert_eq!(p.relays.len(), sc.prob.graph.n_stages(), "{label}: wrong path length");
        for (s, relay) in p.relays.iter().enumerate() {
            assert!(
                sc.prob.graph.stages[s].contains(relay),
                "{label}: relay {relay} not in stage {s}"
            );
            assert!(alive[relay.0], "{label}: dead relay {relay} routed");
        }
    }
}

/// The conformance body.  `mk` builds a fresh policy for a policy seed
/// over the given scenario.
fn conformance<R: RoutingPolicy>(label: &str, sc: &Scenario, mk: impl Fn(&Scenario, u64) -> R) {
    let n = sc.topo.n();
    let all_alive = vec![true; n];

    // --- plan validity + ticket/commit ordering ---
    let mut r = mk(sc, 7);
    let (t0, out0) = request_commit(&mut r, &all_alive, &[], false);
    assert_plan_valid(sc, &out0.paths, &all_alive, label);
    assert!(!out0.stale, "{label}: clean commit marked stale");
    assert_eq!(out0.rounds, r.last_plan_rounds(), "{label}: rounds out of sync");
    assert_eq!(
        out0.committed_at, t0.ready_after_s,
        "{label}: blocking claim must be request + charge"
    );

    // --- dead-node exclusion (a re-plan after a kill) ---
    let victim = out0.paths[0].relays[0];
    let mut alive = all_alive.clone();
    alive[victim.0] = false;
    let (t1, out1) = request_commit(&mut r, &alive, &[victim], true);
    assert!(t1.id > t0.id, "{label}: ticket ids must strictly increase");
    assert_eq!(t1.invalidated, vec![victim], "{label}: dirty must seed the ticket");
    assert_plan_valid(sc, &out1.paths, &alive, label);
    for p in &out1.paths {
        assert!(!p.relays.contains(&victim), "{label}: dead node {victim} still routed");
    }

    // --- choose_replacement picks from the offered candidates only ---
    let stage = 0;
    let cands: Vec<NodeId> = sc.prob.graph.stages[stage]
        .iter()
        .filter(|&&m| m != victim)
        .copied()
        .collect();
    let prev = sc.prob.graph.data_nodes[0];
    let next = sc.prob.graph.stages[stage + 1][0];
    let pick = r.choose_replacement(prev, next, &cands);
    assert!(
        pick.map(|m| cands.contains(&m)).unwrap_or(false),
        "{label}: replacement must come from the candidate list"
    );
    assert_eq!(
        r.choose_replacement(prev, next, &[]),
        None,
        "{label}: no candidates, no replacement"
    );

    // --- determinism per seed: same seed + same request sequence ---
    let run = |seed: u64| {
        let mut r = mk(sc, seed);
        let (_, a) = request_commit(&mut r, &all_alive, &[], false);
        let mut alive = all_alive.clone();
        let victim = a.paths[0].relays[0];
        alive[victim.0] = false;
        let (_, b) = request_commit(&mut r, &alive, &[victim], true);
        (a.paths, b.paths)
    };
    assert_eq!(run(21), run(21), "{label}: plans diverged across identical runs");
}

#[test]
fn gwtf_conforms_to_the_routing_policy_contract() {
    let sc = scenario(41);
    conformance("gwtf", &sc, |sc, seed| {
        GwtfRouter::from_scenario(sc, FlowParams::default(), seed)
    });
}

#[test]
fn swarm_adapter_conforms_to_the_routing_policy_contract() {
    let sc = scenario(42);
    conformance("swarm", &sc, |sc, seed| {
        let topo = sc.topo.clone();
        let payload = sc.sim_cfg.payload_bytes;
        let comm: gwtf::baselines::CostFn = Arc::new(move |i, j| topo.comm(i, j, payload));
        BlockingPlanAdapter::new(SwarmRouter::from_problem(&sc.prob, comm, seed))
    });
}

#[test]
fn dtfm_adapter_conforms_to_the_routing_policy_contract() {
    let sc = scenario(43);
    conformance("dtfm", &sc, |sc, seed| {
        let topo = sc.topo.clone();
        let payload = sc.sim_cfg.payload_bytes;
        let cost: gwtf::baselines::CostFn = Arc::new(move |i, j| topo.cost(i, j, payload));
        BlockingPlanAdapter::new(DtfmRouter::new(
            sc.prob.graph.clone(),
            sc.prob.demand.clone(),
            cost,
            GaParams { generations: 40, ..Default::default() },
            seed,
        ))
    });
}
