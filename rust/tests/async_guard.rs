//! Test-sized bounded-staleness sweep + acceptance gate (ISSUE 7).
//!
//! Runs the async sweep (heterogeneous Table II shape, continuous-clock
//! Poisson churn) with tiny rep/iteration counts, asserts the tentpole's
//! acceptance properties —
//!
//! - **every staleness bound beats the synchronous barrier on goodput**
//!   (completed microbatches per makespan second): each arm sees the
//!   same topologies and churn processes (the bound consumes no
//!   randomness), and a rolling per-stage exchange overlaps the
//!   microbatch tail while the barrier extends it, and
//! - **goodput is monotone non-decreasing in the bound**: a larger `s`
//!   can only defer less (deferral is the sole mechanism by which the
//!   bound costs time) —
//!
//! and maintains the `test_sized` profile of `BENCH_async.json` at the
//! repo root (capture on first run / `GWTF_UPDATE_ASYNC=1`, then a 2x
//! regression gate on the sync-arm makespan).  The full-size sweep is
//! `gwtf bench async`, which fills the `full` profile of the same file.
//! CI runs this test in the guard step and the `arm-baselines` job
//! commits the captured profile on `main`.

use gwtf::experiments::{
    async_json_path, read_async_profile, run_async, update_async_json, AsyncCase, AsyncOpts,
};

fn opts() -> AsyncOpts {
    AsyncOpts { bounds: vec![1, 2, 4], churn_p: 0.2, reps: 2, iters_per_rep: 3, seed: 7 }
}

#[test]
fn async_goodput_beats_sync_and_is_monotone_in_the_bound() {
    // Keep a bounded event ring armed: if any gate below fails, the tail
    // of the simulated timeline lands on stderr + bench_results/.
    let _flight = gwtf::trace::flight::arm_flight_recorder("async_guard", 4096);
    let (table, report) = run_async(&opts()).unwrap();

    // Every arm produced samples and completed work.
    assert_eq!(table.cells.len(), 4, "sync + 3 bounds");
    for ((row, col), acc) in &table.cells {
        assert_eq!(acc.throughput.len(), 2 * 3, "{row}/{col}: 2 reps x 3 iterations");
        assert!(acc.throughput.iter().sum::<f64>() > 0.0, "{row}/{col} completed nothing");
    }

    // Acceptance 1: every staleness bound beats the synchronous barrier
    // on goodput.  Identical scenarios per rep; removing the barrier
    // strictly shortens every fault-free iteration and the churn draws
    // are shared, so the win must survive the averaging.
    let sync = report.case(0).expect("sync reference arm");
    assert!(sync.goodput() > 0.0);
    assert_eq!(sync.staleness_mean, 0.0, "barrier mode trains on fresh weights");
    assert_eq!(sync.deferred_total, 0.0, "no admission rule under the barrier");
    let arms: Vec<&AsyncCase> =
        opts().bounds.iter().map(|&s| report.case(s).expect("async arm")).collect();
    for arm in &arms {
        assert!(arm.agg_mean_s > 0.0, "s={}: rolling exchanges still charged", arm.staleness);
        assert!(
            arm.goodput() > sync.goodput(),
            "s={}: rolling aggregation must out-goodput the barrier: {} vs {}",
            arm.staleness,
            arm.goodput(),
            sync.goodput()
        );
    }

    // Acceptance 2: goodput is monotone non-decreasing in the bound.
    // Deferral is the only cost of a tighter bound; the 2% slack covers
    // scheduling anomalies when the evolving iter_estimate shifts churn
    // instants between arms.
    for w in arms.windows(2) {
        assert!(
            w[1].goodput() >= 0.98 * w[0].goodput(),
            "goodput fell as the bound loosened: {} @ s={} vs {} @ s={}",
            w[0].goodput(),
            w[0].staleness,
            w[1].goodput(),
            w[1].staleness
        );
    }

    // Baseline: capture when null/missing (or on explicit request),
    // otherwise gate the sync-arm total makespan at 2x (deterministic
    // per seed; the headroom covers libm-level drift across machines).
    let path = async_json_path();
    let update = std::env::var("GWTF_UPDATE_ASYNC").is_ok();
    match (update, read_async_profile(&path, "test_sized")) {
        (false, Some(baseline)) => {
            let base = baseline.case(0).expect("baseline sync arm");
            assert!(
                sync.makespan_total_s <= 2.0 * base.makespan_total_s,
                "sync-arm makespan regressed >2x: {} vs baseline {} \
                 (GWTF_UPDATE_ASYNC=1 to re-baseline intentionally)",
                sync.makespan_total_s,
                base.makespan_total_s
            );
        }
        (update, _) => {
            update_async_json(&path, "test_sized", &report).unwrap();
            eprintln!(
                "async test_sized profile {} at {} — commit BENCH_async.json to arm \
                 the regression gate",
                if update {
                    "re-captured (GWTF_UPDATE_ASYNC)"
                } else {
                    "was null/missing; captured"
                },
                path.display()
            );
        }
    }
}
