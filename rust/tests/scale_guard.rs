//! Test-sized scale bench + planner-round regression gate (ISSUE 3),
//! extended with the 1000-relay raw-speed profile (ISSUE 6) and the
//! 10000-relay sparse-substrate profile (ISSUE 10).
//!
//! Runs the 100/200-relay overlay scenario plus GWTF-only 1000- and
//! 10000-relay cases with tiny rep/iteration counts, records planner
//! wall time, protocol rounds, engine event throughput, peak RSS and
//! the substrate's resident-memory telemetry, and maintains the
//! `test_sized` profile of `BENCH_scale.json` at the repo root:
//!
//! - When the committed profile is `null` or predates the 10000-relay
//!   case (first run on a fresh machine, or the first run after the
//!   sparse-substrate change), the measurement is captured and written —
//!   **commit the updated `BENCH_scale.json`** to arm the gate (the
//!   `arm-baselines` CI job does this automatically on `main`).
//! - When an armed baseline exists, the 100-, 1000- and 10000-relay
//!   GWTF planner rounds must stay within 2x of it.  Rounds are
//!   deterministic per seed, so that gate is stable across machines up
//!   to libm-level annealer differences — hence the 2x headroom.  At
//!   10000 relays the events/sec figure is additionally gated at 2x:
//!   the sparse substrate is a raw-speed claim, and a half-speed engine
//!   there means an n² path crept back in.  (Wall clock varies across
//!   machines; the arm-baselines job captures on the same runner family
//!   that later enforces, and the 2x headroom absorbs runner jitter.)
//! - `GWTF_UPDATE_SCALE_BASELINE=1` re-captures after an intentional
//!   planner or substrate change.
//!
//! The full-size sweep is `cargo bench --bench scale_bench` /
//! `gwtf bench scale --gwtf-relays 10000`, which fills the `full`
//! profile of the same file.

use gwtf::experiments::{
    read_scale_profile, run_scale, scale_json_path, update_scale_json, ScaleOpts,
};

fn opts() -> ScaleOpts {
    ScaleOpts {
        sizes: vec![100, 200],
        // The raw-speed gates: 1000 and 10000 relays, GWTF only (the
        // baselines' global O(n²) scans would dominate the test's wall
        // time without informing a gate that compares GWTF to itself).
        // At 10000 the scale scenario runs the procedural link store
        // and the sparse congestion cache — the path the resident-
        // memory assertions below pin.
        gwtf_only_sizes: vec![1000, 10000],
        reps: 1,
        iters_per_rep: 2,
        seed: 7,
        churn_p: 0.2,
        dtfm_generations: 10,
        // Exercise the threaded candidate-evaluation path; plans (and
        // so every gated counter) are bit-identical at any thread
        // count — rust/tests/dense_parity.rs pins that.
        planner_threads: 4,
    }
}

#[test]
fn scale_completes_at_100_200_1000_and_10000_relays_and_gates_planner_rounds() {
    // Keep a bounded event ring armed: if any gate below fails, the tail
    // of the simulated timeline lands on stderr + bench_results/.
    let _flight = gwtf::trace::flight::arm_flight_recorder("scale_guard", 4096);
    let (table, report) = run_scale(&opts()).unwrap();

    // Acceptance: completes at 100 and 200 relays under 20% Poisson
    // churn, all three systems produce cells, GWTF reports its rounds.
    for &n in &[100usize, 200] {
        let row = format!("scale {n}");
        for col in ["gwtf", "swarm", "dtfm"] {
            assert!(
                table.cells.contains_key(&(row.clone(), col.to_string())),
                "missing cell {row}/{col}"
            );
        }
        let g = report.case(n, "gwtf").expect("gwtf case");
        assert!(g.throughput_total > 0.0, "{n}-relay overlay run routed nothing");
        assert!(g.plan_rounds_total > 0, "{n}-relay planner reported no rounds");
        assert_eq!(g.plan_calls, 2, "one (re)plan per iteration");
        // Below the procedural threshold the substrate stays on the
        // legacy Dense arm: n² resident links, no congestion cache.
        assert_eq!(g.resident_link_entries, n * n, "{n}-relay dense arm is n²");
        assert_eq!(g.resident_cache_entries, 0, "{n}-relay runs without the memo");
    }

    // Raw-speed acceptance (ISSUE 6 at 1000, ISSUE 10 at 10000): the
    // 10-region, 20%-Poisson-churn scenario completes inside the
    // test-sized run, GWTF only, with engine/planner throughput and the
    // substrate's resident footprint recorded.
    for &n in &[1000usize, 10000] {
        let g = report.case(n, "gwtf").unwrap_or_else(|| panic!("{n}-relay gwtf case"));
        assert!(g.throughput_total > 0.0, "{n}-relay overlay run routed nothing");
        assert!(g.plan_rounds_total > 0, "{n}-relay planner reported no rounds");
        assert_eq!(g.plan_calls, 2, "one (re)plan per iteration");
        assert!(g.events_total > 0, "engine events must be counted");
        assert!(report.case(n, "swarm").is_none(), "{n} relays is GWTF-only");
        // The sparse-substrate acceptance: resident topology memory is
        // O(regions²) — the procedural store holds per-region-pair
        // ranges, not per-relay-pair params — and the congestion memo
        // holds only the edges the planner actually touched, far below
        // the n² (and 2·n²) the dense arms would materialize.
        assert!(
            g.resident_link_entries < n,
            "{n}-relay procedural store must be O(regions²), got {} resident entries",
            g.resident_link_entries
        );
        assert!(
            g.resident_cache_entries > 0,
            "{n}-relay congestion-aware planning must touch the memo"
        );
        // The overlay bounds the planner to O(n·fanout) candidate edges
        // (fanout 8 here), so touched ≪ n²; the bound leaves headroom
        // over that while still refusing any whole-matrix population.
        assert!(
            g.resident_cache_entries < n * n / 10,
            "{n}-relay sparse cache resident entries ({}) approach n² — \
             the lazy arm is not lazy",
            g.resident_cache_entries
        );
        eprintln!(
            "scale {n}/gwtf: {} engine events ({:.0} events/sec), planner {:.1} ms \
             over {} rounds, {} resident links + {} cached edges, peak RSS {:.1} MiB",
            g.events_total,
            g.events_per_sec(),
            g.plan_wall_ms,
            g.plan_rounds_total,
            g.resident_link_entries,
            g.resident_cache_entries,
            g.peak_rss_mib
        );
    }
    // Both procedural cases share one region grid, so their resident
    // link tables are the same O(regions²) size — 10x the relays, zero
    // extra resident topology.
    let g1k = report.case(1000, "gwtf").unwrap();
    let g10k = report.case(10000, "gwtf").unwrap();
    assert_eq!(
        g1k.resident_link_entries, g10k.resident_link_entries,
        "procedural resident size must not grow with n"
    );
    // Peak RSS lands in the report wherever /proc exposes it (the probe
    // returns 0 elsewhere, and the figure is informational, never gated).
    if gwtf::util::mem::peak_rss_mib() > 0.0 {
        assert!(report.peak_rss_mib > 0.0, "report must record peak RSS");
        assert!(g10k.peak_rss_mib > 0.0, "10000-relay case must record peak RSS");
    }

    let path = scale_json_path();
    let update = std::env::var("GWTF_UPDATE_SCALE_BASELINE").is_ok();
    let baseline = read_scale_profile(&path, "test_sized");
    // Gate only against a baseline that covers the 10000-relay case; an
    // older capture (pre-sparse-substrate format) is re-captured instead.
    let armed = baseline
        .as_ref()
        .is_some_and(|b| b.case(1000, "gwtf").is_some() && b.case(10000, "gwtf").is_some());
    if !update && armed {
        let baseline = baseline.unwrap();
        for &n in &[100usize, 1000, 10000] {
            let base = baseline.case(n, "gwtf").expect("armed baseline gwtf case");
            let fresh = report.case(n, "gwtf").unwrap();
            assert!(
                fresh.plan_rounds_total <= 2 * base.plan_rounds_total,
                "{n}-relay planner rounds regressed >2x: {} vs baseline {} \
                 (GWTF_UPDATE_SCALE_BASELINE=1 to re-baseline intentionally)",
                fresh.plan_rounds_total,
                base.plan_rounds_total
            );
            assert!(
                fresh.cold_rounds <= 2 * base.cold_rounds,
                "{n}-relay cold-plan convergence regressed >2x: {} vs baseline {}",
                fresh.cold_rounds,
                base.cold_rounds
            );
        }
        // The 10000-relay events/sec figure is the sparse substrate's
        // raw-speed claim: dropping below half the committed baseline
        // means an n² path crept back into the per-event kernel.
        let base10k = baseline.case(10000, "gwtf").unwrap();
        let fresh10k = report.case(10000, "gwtf").unwrap();
        if base10k.events_per_sec() > 0.0 {
            assert!(
                2.0 * fresh10k.events_per_sec() >= base10k.events_per_sec(),
                "10000-relay engine throughput regressed >2x: {:.0} events/sec vs \
                 baseline {:.0} (GWTF_UPDATE_SCALE_BASELINE=1 to re-baseline \
                 intentionally)",
                fresh10k.events_per_sec(),
                base10k.events_per_sec()
            );
        }
    } else {
        update_scale_json(&path, "test_sized", &report).unwrap();
        let where_ = if std::env::var("GITHUB_ACTIONS").is_ok() {
            "NOTE: on a CI runner the capture is discarded with the checkout \
             unless the arm-baselines job commits it"
        } else {
            "commit BENCH_scale.json to arm the regression gate"
        };
        let reason = if update {
            "re-captured (GWTF_UPDATE_SCALE_BASELINE)"
        } else if baseline.is_some() {
            "predated the 10000-relay profile; re-captured"
        } else {
            "was null/missing; captured"
        };
        eprintln!("scale baseline {reason} at {} — {where_}", path.display());
    }
}
