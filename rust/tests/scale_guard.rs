//! Test-sized scale bench + planner-round regression gate (ISSUE 3),
//! extended with the 1000-relay raw-speed profile (ISSUE 6).
//!
//! Runs the 100/200-relay overlay scenario plus a GWTF-only 1000-relay
//! case with tiny rep/iteration counts, records planner wall time,
//! protocol rounds and engine event throughput, and maintains the
//! `test_sized` profile of `BENCH_scale.json` at the repo root:
//!
//! - When the committed profile is `null` or predates the 1000-relay
//!   case (first run on a fresh machine, or the first run after the
//!   raw-speed change), the measurement is captured and written —
//!   **commit the updated `BENCH_scale.json`** to arm the gate (the
//!   `arm-baselines` CI job does this automatically on `main`).
//! - When an armed baseline exists, the 100- and 1000-relay GWTF
//!   planner rounds must stay within 2x of it.  Rounds are
//!   deterministic per seed, so the gate is stable across machines up
//!   to libm-level annealer differences — hence the 2x headroom (wall
//!   time and events/sec are recorded but never gated; CI machines
//!   vary).
//! - `GWTF_UPDATE_SCALE_BASELINE=1` re-captures after an intentional
//!   planner change.
//!
//! The full-size sweep is `cargo bench --bench scale_bench` /
//! `gwtf bench scale`, which fills the `full` profile of the same file.

use gwtf::experiments::{
    read_scale_profile, run_scale, scale_json_path, update_scale_json, ScaleOpts,
};

fn opts() -> ScaleOpts {
    ScaleOpts {
        sizes: vec![100, 200],
        // The raw-speed gate: 1000 relays, GWTF only (the baselines'
        // global O(n²) scans would dominate the test's wall time
        // without informing a gate that compares GWTF to itself).
        gwtf_only_sizes: vec![1000],
        reps: 1,
        iters_per_rep: 2,
        seed: 7,
        churn_p: 0.2,
        dtfm_generations: 10,
        // Exercise the threaded candidate-evaluation path; plans (and
        // so every gated counter) are bit-identical at any thread
        // count — rust/tests/dense_parity.rs pins that.
        planner_threads: 4,
    }
}

#[test]
fn scale_completes_at_100_200_and_1000_relays_and_gates_planner_rounds() {
    // Keep a bounded event ring armed: if any gate below fails, the tail
    // of the simulated timeline lands on stderr + bench_results/.
    let _flight = gwtf::trace::flight::arm_flight_recorder("scale_guard", 4096);
    let (table, report) = run_scale(&opts()).unwrap();

    // Acceptance: completes at 100 and 200 relays under 20% Poisson
    // churn, all three systems produce cells, GWTF reports its rounds.
    for &n in &[100usize, 200] {
        let row = format!("scale {n}");
        for col in ["gwtf", "swarm", "dtfm"] {
            assert!(
                table.cells.contains_key(&(row.clone(), col.to_string())),
                "missing cell {row}/{col}"
            );
        }
        let g = report.case(n, "gwtf").expect("gwtf case");
        assert!(g.throughput_total > 0.0, "{n}-relay overlay run routed nothing");
        assert!(g.plan_rounds_total > 0, "{n}-relay planner reported no rounds");
        assert_eq!(g.plan_calls, 2, "one (re)plan per iteration");
    }

    // Raw-speed acceptance (ISSUE 6): the 1000-relay, 10-region,
    // 20%-Poisson-churn scenario completes inside the test-sized run,
    // GWTF only, with engine/planner throughput recorded.
    let g1k = report.case(1000, "gwtf").expect("1000-relay gwtf case");
    assert!(g1k.throughput_total > 0.0, "1000-relay overlay run routed nothing");
    assert!(g1k.plan_rounds_total > 0, "1000-relay planner reported no rounds");
    assert_eq!(g1k.plan_calls, 2, "one (re)plan per iteration");
    assert!(g1k.events_total > 0, "engine events must be counted");
    assert!(report.case(1000, "swarm").is_none(), "1000 relays is GWTF-only");
    eprintln!(
        "scale 1000/gwtf: {} engine events ({:.0} events/sec), planner {:.1} ms \
         over {} rounds (informational; only rounds are gated)",
        g1k.events_total,
        g1k.events_per_sec(),
        g1k.plan_wall_ms,
        g1k.plan_rounds_total
    );

    let path = scale_json_path();
    let update = std::env::var("GWTF_UPDATE_SCALE_BASELINE").is_ok();
    let baseline = read_scale_profile(&path, "test_sized");
    // Gate only against a baseline that covers the 1000-relay case; an
    // older capture (pre-raw-speed format) is re-captured instead.
    let armed = baseline.as_ref().is_some_and(|b| b.case(1000, "gwtf").is_some());
    if !update && armed {
        let baseline = baseline.unwrap();
        for &n in &[100usize, 1000] {
            let base = baseline.case(n, "gwtf").expect("armed baseline gwtf case");
            let fresh = report.case(n, "gwtf").unwrap();
            assert!(
                fresh.plan_rounds_total <= 2 * base.plan_rounds_total,
                "{n}-relay planner rounds regressed >2x: {} vs baseline {} \
                 (GWTF_UPDATE_SCALE_BASELINE=1 to re-baseline intentionally)",
                fresh.plan_rounds_total,
                base.plan_rounds_total
            );
            assert!(
                fresh.cold_rounds <= 2 * base.cold_rounds,
                "{n}-relay cold-plan convergence regressed >2x: {} vs baseline {}",
                fresh.cold_rounds,
                base.cold_rounds
            );
        }
    } else {
        update_scale_json(&path, "test_sized", &report).unwrap();
        let where_ = if std::env::var("GITHUB_ACTIONS").is_ok() {
            "NOTE: on a CI runner the capture is discarded with the checkout \
             unless the arm-baselines job commits it"
        } else {
            "commit BENCH_scale.json to arm the regression gate"
        };
        let reason = if update {
            "re-captured (GWTF_UPDATE_SCALE_BASELINE)"
        } else if baseline.is_some() {
            "predated the 1000-relay profile; re-captured"
        } else {
            "was null/missing; captured"
        };
        eprintln!("scale baseline {reason} at {} — {where_}", path.display());
    }
}
