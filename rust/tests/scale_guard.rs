//! Test-sized scale bench + planner-round regression gate (ISSUE 3).
//!
//! Runs the 100/200-relay overlay scenario with tiny rep/iteration
//! counts, records planner wall time and protocol rounds, and maintains
//! the `test_sized` profile of `BENCH_scale.json` at the repo root:
//!
//! - When the committed profile is `null` (first run on a fresh
//!   machine), the measurement is captured and written — **commit the
//!   updated `BENCH_scale.json`** to arm the gate (the `arm-baselines`
//!   CI job does this automatically on `main`).
//! - When a baseline exists, the 100-relay GWTF planner rounds must stay
//!   within 2x of it.  Rounds are deterministic per seed, so the gate is
//!   stable across machines up to libm-level annealer differences —
//!   hence the 2x headroom (wall time is recorded but never gated; CI
//!   machines vary).
//! - `GWTF_UPDATE_SCALE_BASELINE=1` re-captures after an intentional
//!   planner change.
//!
//! The full-size sweep is `cargo bench --bench scale_bench` /
//! `gwtf bench scale`, which fills the `full` profile of the same file.

use gwtf::experiments::{
    read_scale_profile, run_scale, scale_json_path, update_scale_json, ScaleOpts,
};

fn opts() -> ScaleOpts {
    ScaleOpts {
        sizes: vec![100, 200],
        reps: 1,
        iters_per_rep: 2,
        seed: 7,
        churn_p: 0.2,
        dtfm_generations: 10,
    }
}

#[test]
fn scale_completes_at_100_and_200_relays_and_gates_planner_rounds() {
    let (table, report) = run_scale(&opts()).unwrap();

    // Acceptance: completes at 100 and 200 relays under 20% Poisson
    // churn, all three systems produce cells, GWTF reports its rounds.
    for &n in &[100usize, 200] {
        let row = format!("scale {n}");
        for col in ["gwtf", "swarm", "dtfm"] {
            assert!(
                table.cells.contains_key(&(row.clone(), col.to_string())),
                "missing cell {row}/{col}"
            );
        }
        let g = report.case(n, "gwtf").expect("gwtf case");
        assert!(g.throughput_total > 0.0, "{n}-relay overlay run routed nothing");
        assert!(g.plan_rounds_total > 0, "{n}-relay planner reported no rounds");
        assert_eq!(g.plan_calls, 2, "one (re)plan per iteration");
    }

    let path = scale_json_path();
    let update = std::env::var("GWTF_UPDATE_SCALE_BASELINE").is_ok();
    match (update, read_scale_profile(&path, "test_sized")) {
        (false, Some(baseline)) => {
            let base = baseline.case(100, "gwtf").expect("baseline 100-relay gwtf case");
            let fresh = report.case(100, "gwtf").unwrap();
            assert!(
                fresh.plan_rounds_total <= 2 * base.plan_rounds_total,
                "100-relay planner rounds regressed >2x: {} vs baseline {} \
                 (GWTF_UPDATE_SCALE_BASELINE=1 to re-baseline intentionally)",
                fresh.plan_rounds_total,
                base.plan_rounds_total
            );
            assert!(
                fresh.cold_rounds <= 2 * base.cold_rounds,
                "100-relay cold-plan convergence regressed >2x: {} vs baseline {}",
                fresh.cold_rounds,
                base.cold_rounds
            );
        }
        (update, _) => {
            update_scale_json(&path, "test_sized", &report).unwrap();
            let where_ = if std::env::var("GITHUB_ACTIONS").is_ok() {
                "NOTE: on a CI runner the capture is discarded with the checkout \
                 unless the arm-baselines job commits it"
            } else {
                "commit BENCH_scale.json to arm the regression gate"
            };
            eprintln!(
                "scale baseline {} at {} — {where_}",
                if update {
                    "re-captured (GWTF_UPDATE_SCALE_BASELINE)"
                } else {
                    "was null/missing; captured"
                },
                path.display()
            );
        }
    }
}
