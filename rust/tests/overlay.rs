//! Integration tests for the gossip-based partial-view overlay (ISSUE 3):
//!
//! - **Parity**: with fanout >= n-1 every directed view covers its whole
//!   adjacent stage, and neighbor-scoped planning must reproduce the
//!   pre-overlay global-scan planner *bit for bit* — identical paths,
//!   identical protocol rounds, identical Eq. 2 cost bits, both for cold
//!   plans and warm replans, and end-to-end through the engine under
//!   churn.
//! - **Connectivity**: the union of active views (fwd + bwd + key ring)
//!   over alive relays stays connected across Poisson churn and
//!   reconciliation — the ring repair makes this a hard invariant, not a
//!   probabilistic one.
//! - **Determinism**: same seeds, same churn stream => byte-identical
//!   neighbor maps.
//! - **Scan bound** (acceptance): with the default fanout, Request
//!   Change examines at most k·chains candidate pairs per round.

use std::collections::{BTreeMap, BTreeSet};

use gwtf::coordinator::GwtfRouter;
use gwtf::cost::NodeId;
use gwtf::flow::decentralized::DecentralizedFlow;
use gwtf::flow::FlowParams;
use gwtf::net::{GossipConfig, Overlay};
use gwtf::sim::scenario::{build, ScenarioConfig, DEFAULT_OVERLAY_FANOUT};
use gwtf::sim::training::RoutingPolicy;
use gwtf::sim::{ChurnModel, ChurnProcess, Engine, EventSource};

/// A GwtfRouter over `sc` with a full-fanout overlay attached (fanout =
/// total node count >= any stage size => global views).
fn full_overlay_router(sc: &gwtf::sim::scenario::Scenario, seed: u64) -> GwtfRouter {
    let mut r = GwtfRouter::from_scenario(sc, FlowParams::default(), seed);
    r.attach_overlay(Overlay::build(
        &sc.prob.graph,
        sc.topo.n(),
        GossipConfig { fanout: sc.topo.n(), ..Default::default() },
        0xFA11,
    ));
    r
}

#[test]
fn parity_full_fanout_matches_global_planner_bitwise() {
    let sc = build(&ScenarioConfig::table2(true, 0.0, 77));
    let n = sc.topo.n();
    let mut base = GwtfRouter::from_scenario(&sc, FlowParams::default(), 7);
    let mut full = full_overlay_router(&sc, 7);

    let mut alive = vec![true; n];
    let (pa, _) = base.plan(&alive);
    let (pb, _) = full.plan(&alive);
    assert_eq!(pa, pb, "cold plans diverge");
    assert_eq!(base.last_rounds, full.last_rounds, "cold-plan protocol rounds diverge");
    assert_eq!(base.last_cost.to_bits(), full.last_cost.to_bits(), "Eq. 2 cost bits diverge");

    // crash a routed relay -> warm replan
    let victim = pa[0].relays[1];
    alive[victim.0] = false;
    let (ra, _) = base.replan(&alive, &[victim]);
    let (rb, _) = full.replan(&alive, &[victim]);
    assert_eq!(ra, rb, "warm replans diverge after a crash");
    assert_eq!(base.last_rounds, full.last_rounds);
    assert_eq!(base.last_cost.to_bits(), full.last_cost.to_bits());

    // rejoin -> another warm replan (overlay re-admits the relay)
    alive[victim.0] = true;
    let (ja, _) = base.replan(&alive, &[]);
    let (jb, _) = full.replan(&alive, &[]);
    assert_eq!(ja, jb, "warm replans diverge after a rejoin");
    assert_eq!(base.last_cost.to_bits(), full.last_cost.to_bits());
}

#[test]
fn parity_full_fanout_engine_run_under_churn_bitwise() {
    // End-to-end: same engine seed, Bernoulli 20% churn, warm replans;
    // the full-fanout overlay router must move not a single metric bit
    // relative to the pre-overlay planner (mid-iteration recovery and
    // crash events included).
    let run = |with_overlay: bool| {
        let sc = build(&ScenarioConfig::table2(true, 0.2, 91));
        let mut router = if with_overlay {
            full_overlay_router(&sc, 13)
        } else {
            GwtfRouter::from_scenario(&sc, FlowParams::default(), 13)
        };
        let mut engine = Engine::from_scenario(&sc, 29);
        engine.warm_replan = true;
        (0..5)
            .map(|_| engine.step(&sc.prob, &mut router))
            .map(|m| {
                (
                    m.completed,
                    m.dropped,
                    m.fwd_recoveries,
                    m.bwd_recoveries,
                    m.replan_rounds,
                    m.makespan_s.to_bits(),
                    m.comm_s.to_bits(),
                    m.wasted_gpu_s.to_bits(),
                )
            })
            .collect::<Vec<_>>()
    };
    assert_eq!(run(false), run(true), "k = n-1 overlay must be invisible in the metrics");
}

/// Undirected overlay graph over alive relays; true iff connected.
fn overlay_connected(ov: &Overlay) -> bool {
    let alive = ov.alive_relays();
    if alive.len() <= 1 {
        return true;
    }
    let mut adj: BTreeMap<NodeId, BTreeSet<NodeId>> = BTreeMap::new();
    for &r in &alive {
        let v = ov.views_of(r).expect("alive relay has views");
        for p in v.planning_peers() {
            if alive.contains(&p) {
                adj.entry(r).or_default().insert(p);
                adj.entry(p).or_default().insert(r);
            }
        }
    }
    let mut seen = BTreeSet::new();
    let mut stack = vec![alive[0]];
    while let Some(x) = stack.pop() {
        if !seen.insert(x) {
            continue;
        }
        if let Some(ns) = adj.get(&x) {
            stack.extend(ns.iter().copied().filter(|m| !seen.contains(m)));
        }
    }
    seen.len() == alive.len()
}

#[test]
fn prop_active_view_union_stays_connected_under_poisson_churn() {
    for seed in 0..8u64 {
        let cfg = ScenarioConfig::scale(48, 0.3, 100 + seed);
        let sc = build(&cfg);
        let n = sc.topo.n();
        let mut ov = Overlay::build(
            &sc.prob.graph,
            n,
            GossipConfig { fanout: 4, ..Default::default() },
            seed ^ 0xC0,
        );
        let mut churn =
            ChurnProcess::with_model(ChurnModel::Poisson, n, sc.relays.clone(), 0.3, seed);
        for iter in 0..12 {
            let sched = EventSource::sample(&mut churn, iter, 240.0);
            // mid-iteration: detector rounds run against the live truth
            for _ in 0..4 {
                ov.gossip_round(&churn.alive);
            }
            // engine applies mid-iteration joins after the iteration
            for &(node, _) in &sched.joins {
                churn.alive[node.0] = true;
            }
            // next plan reconciles the overlay with the new liveness
            ov.reconcile(&churn.alive);

            assert!(
                overlay_connected(&ov),
                "seed {seed} iter {iter}: overlay partitioned ({} alive)",
                ov.alive_relays().len()
            );
            for &r in &ov.alive_relays() {
                let v = ov.views_of(r).unwrap();
                assert!(v.fwd.active.len() <= 4, "fwd view exceeds fanout");
                assert!(v.bwd.active.len() <= 4, "bwd view exceeds fanout");
                for p in v.planning_peers() {
                    assert!(
                        churn.alive[p.0],
                        "seed {seed} iter {iter}: {r} still sees dead {p} after reconcile"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_overlay_views_deterministic_per_seed() {
    for seed in 0..6u64 {
        let run = || {
            let cfg = ScenarioConfig::scale(36, 0.4, 50 + seed);
            let sc = build(&cfg);
            let n = sc.topo.n();
            let mut ov = Overlay::build(
                &sc.prob.graph,
                n,
                GossipConfig { fanout: 5, ..Default::default() },
                seed ^ 0xD5,
            );
            let mut churn =
                ChurnProcess::with_model(ChurnModel::Poisson, n, sc.relays.clone(), 0.4, seed);
            let mut maps = Vec::new();
            for iter in 0..8 {
                let sched = EventSource::sample(&mut churn, iter, 240.0);
                for _ in 0..3 {
                    ov.gossip_round(&churn.alive);
                }
                for &(node, _) in &sched.joins {
                    churn.alive[node.0] = true;
                }
                ov.reconcile(&churn.alive);
                maps.push(ov.neighbor_map());
            }
            maps
        };
        assert_eq!(run(), run(), "seed {seed}: neighbor maps diverged across runs");
    }
}

#[test]
fn acceptance_change_scans_bounded_by_fanout_times_chains() {
    // 100 relays at the default fanout: every round's Request Change
    // candidate scans stay within k·chains (the O(chains·k) bound).
    let cfg = ScenarioConfig::scale(100, 0.0, 3);
    let sc = build(&cfg);
    let ov = Overlay::build(
        &sc.prob.graph,
        sc.topo.n(),
        GossipConfig { fanout: DEFAULT_OVERLAY_FANOUT, ..Default::default() },
        0xB0B,
    );
    let mut flow = DecentralizedFlow::new(&sc.prob, FlowParams::default(), 3);
    flow.set_neighbors(ov.neighbor_map());
    let stats = flow.run(120, 8);
    assert!(flow.complete_flows() > 0, "overlay-scoped planning must route flows");
    let k = DEFAULT_OVERLAY_FANOUT;
    for s in &stats {
        assert!(
            s.change_scans <= k * s.chains.max(1),
            "round {}: {} change scans > k·chains = {}·{}",
            s.round,
            s.change_scans,
            k,
            s.chains
        );
    }
    // neighbor lists themselves are bounded: 2 directed views + ring +
    // the always-visible data nodes
    let bound = 2 * k + 1 + sc.data_nodes.len();
    for (r, peers) in ov.neighbor_map() {
        assert!(peers.len() <= bound, "{r}: {} peers > {bound}", peers.len());
    }
}

#[test]
fn overlay_router_routes_under_partial_views_at_scale() {
    // Sanity beyond the bound: a genuinely partial view (fanout 8 over
    // ~17-relay stages) still routes the demand through the engine.
    let cfg = ScenarioConfig::scale(100, 0.0, 19);
    let sc = build(&cfg);
    let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 19);
    let mut engine = sc.engine(19 ^ 0x1);
    engine.warm_replan = true;
    let mut completed = 0;
    for _ in 0..2 {
        completed += engine.step(&sc.prob, &mut router).completed;
    }
    assert!(completed > 0, "no microbatch completed at 100 relays");
    let rounds = router.last_plan_rounds();
    assert!(rounds > 0, "flow protocol must report its rounds");
}
