//! Statistical validation of the continuous-clock Poisson churn process
//! (ISSUE 2): seeded KS and chi-square checks of the raw inter-arrival
//! stream against its configured exponential law, rate equivalence with
//! the legacy Bernoulli model, and engine-level behaviour under Poisson
//! churn.  Sample sizes (>= 10k arrivals) are RNG-only work, cheap in
//! both debug and the CI release-test profile.
//!
//! All thresholds are deliberately generous multiples of the relevant
//! sampling noise (5-9 sigma) so the fixed seeds cannot flake, while
//! still failing hard for a wrong distribution or a wrong rate mapping
//! (e.g. `-ln(1-p)` instead of `p` misses the rate bound).

use gwtf::coordinator::GwtfRouter;
use gwtf::cost::NodeId;
use gwtf::flow::FlowParams;
use gwtf::sim::churn_process::PoissonChurn;
use gwtf::sim::scenario::{build, ScenarioConfig};
use gwtf::sim::{ChurnModel, ChurnProcess};
use gwtf::util::stats::{chi_square_edf, ks_statistic};

/// Absolute arrival times (iteration units) of one relay's transition
/// stream over `iters` iterations.
fn arrival_times(rate: f64, seed: u64, iters: usize) -> Vec<f64> {
    let mut pc = PoissonChurn::new(vec![NodeId(0)], rate, seed);
    let mut times = Vec::new();
    for iter in 0..iters {
        for tr in pc.advance_iteration() {
            times.push(iter as f64 + tr.at);
        }
    }
    times
}

#[test]
fn poisson_interarrivals_pass_ks_against_configured_rate() {
    let rate = 0.8;
    let times = arrival_times(rate, 0xC0FFEE, 15_000);
    assert!(times.len() >= 10_000, "need >= 10k arrivals, got {}", times.len());
    let gaps: Vec<f64> = times.windows(2).map(|w| w[1] - w[0]).collect();
    let cdf = |x: f64| 1.0 - (-rate * x).exp();
    let d = ks_statistic(&gaps, cdf);
    // E[D] ~ 0.87/sqrt(n) ~ 0.008 here; 0.02 rejects at far beyond the
    // 0.1% level yet catches a 10% rate error (D ~ 0.037) or any wrong
    // distribution family outright.
    assert!(d < 0.02, "KS statistic {d} too large for Exp({rate}) with n = {}", gaps.len());
}

#[test]
fn poisson_interarrivals_pass_chi_square_against_configured_rate() {
    let rate = 0.8;
    let times = arrival_times(rate, 0xBEEF, 15_000);
    assert!(times.len() >= 10_000, "need >= 10k arrivals, got {}", times.len());
    let gaps: Vec<f64> = times.windows(2).map(|w| w[1] - w[0]).collect();
    let cdf = |x: f64| 1.0 - (-rate * x).exp();
    // 20 equal-probability bins, df = 19: mean 19, std ~6.2.
    let chi = chi_square_edf(&gaps, cdf, 20);
    assert!(chi < 60.0, "chi-square {chi} over 20 bins (df = 19) for Exp({rate})");
}

#[test]
fn poisson_rate_matches_legacy_chance_mapping() {
    // rate_for_chance must reproduce the legacy configs' expected churn:
    // p expected transitions per relay-iteration.
    for &(p, seed) in &[(0.1, 42u64), (0.2, 43u64)] {
        let relays: Vec<NodeId> = (0..16).map(NodeId).collect();
        let mut pc = PoissonChurn::new(relays, PoissonChurn::rate_for_chance(p), seed);
        let iters = 4000;
        let mut count = 0usize;
        for _ in 0..iters {
            count += pc.advance_iteration().len();
        }
        let per_node_iter = count as f64 / (16.0 * iters as f64);
        // ~9 sigma of Poisson counting noise; -ln(1-0.2) = 0.223 (the
        // wrong hazard mapping) overshoots this bound.
        assert!(
            (per_node_iter - p).abs() < 0.08 * p,
            "Poisson churn rate {per_node_iter:.4} vs configured {p}"
        );
    }
}

#[test]
fn bernoulli_and_poisson_agree_on_expected_churn_per_iteration() {
    let p = 0.15;
    let n = 16usize;
    let iters = 4000;

    let relays: Vec<NodeId> = (0..n).map(NodeId).collect();
    let mut bern = ChurnProcess::new(n, relays.clone(), p, 7);
    let mut bern_flips = 0usize;
    for _ in 0..iters {
        let ev = bern.sample_iteration();
        bern_flips += ev.crashes.len() + ev.rejoins.len();
    }

    let mut pois = PoissonChurn::new(relays, PoissonChurn::rate_for_chance(p), 7);
    let mut pois_flips = 0usize;
    for _ in 0..iters {
        pois_flips += pois.advance_iteration().len();
    }

    let expected = p * n as f64 * iters as f64;
    for (name, flips) in [("bernoulli", bern_flips), ("poisson", pois_flips)] {
        assert!(
            (flips as f64 - expected).abs() < 0.08 * expected,
            "{name}: {flips} transitions vs expected {expected}"
        );
    }
}

#[test]
fn poisson_engine_run_is_deterministic_and_sees_mid_iteration_churn() {
    let run = || {
        let mut cfg = ScenarioConfig::table2(true, 0.5, 23);
        cfg.churn_model = ChurnModel::Poisson;
        let sc = build(&cfg);
        let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 23);
        let mut engine = sc.engine(23 ^ 0x1);
        engine.warm_replan = true;
        let mut trace = Vec::new();
        let mut min_alive = sc.relays.len();
        for _ in 0..8 {
            let m = engine.step(&sc.prob, &mut router);
            min_alive = min_alive.min(engine.churn.alive_count());
            trace.push((
                m.completed,
                m.dropped,
                m.makespan_s.to_bits(),
                m.comm_s.to_bits(),
                m.wasted_gpu_s.to_bits(),
            ));
        }
        (trace, min_alive)
    };
    let (trace_a, min_alive) = run();
    let (trace_b, _) = run();
    assert_eq!(trace_a, trace_b, "Poisson churn must be deterministic from seeds");
    // Hazard 0.5 over 16 relays x 8 iterations: ~64 expected transitions;
    // the membership cannot have stayed full throughout.
    assert!(min_alive < 16, "continuous-clock churn never took a relay down");
    assert!(
        trace_a.iter().any(|&(completed, ..)| completed > 0),
        "some iterations must still complete work"
    );
}
