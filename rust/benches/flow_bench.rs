//! `cargo bench --bench flow_bench` — L3 hot-path microbenchmarks for the
//! flow optimizer (EXPERIMENTS.md §Perf).
//!
//! The paper argues the decentralized algorithm's control traffic is
//! negligible next to training ("convergence ... is significantly faster
//! than a training iteration", §V-C); these benches quantify our
//! implementation: per-round step cost, full plan convergence, crash
//! repair, and the exact-solver baseline.

use std::time::Duration;

use gwtf::flow::decentralized::{DecentralizedFlow, FlowParams};
use gwtf::flow::graph::random_problem;
use gwtf::flow::mcmf::mcmf_min_cost;
use gwtf::flow::Annealer;
use gwtf::util::bench::{bench, black_box};
use gwtf::util::Rng;

fn main() {
    let budget = Duration::from_millis(400);
    let mut results = Vec::new();

    // one protocol round on the Table V test-1 instance
    {
        let mut rng = Rng::new(1);
        let prob = random_problem(1, 40, 8, (1.0, 3.0), (1.0, 20.0), &mut rng);
        let mut f = DecentralizedFlow::new(&prob, FlowParams::default(), 1);
        results.push(bench("flow/step (40 relays, 8 stages)", budget, || {
            black_box(f.step());
        }));
    }

    // full plan to steady state
    {
        let mut rng = Rng::new(2);
        let prob = random_problem(1, 40, 8, (1.0, 3.0), (1.0, 20.0), &mut rng);
        let mut seed = 0u64;
        results.push(bench("flow/full-plan (120 rounds max)", budget, || {
            seed += 1;
            let mut f = DecentralizedFlow::new(&prob, FlowParams::default(), seed);
            black_box(f.run(120, 8));
        }));
    }

    // crash repair on an established flow set
    {
        let mut rng = Rng::new(3);
        let prob = random_problem(1, 40, 8, (2.0, 4.0), (1.0, 20.0), &mut rng);
        let mut f = DecentralizedFlow::new(&prob, FlowParams::default(), 3);
        f.run(120, 8);
        let victims: Vec<_> = f.established_paths().iter().map(|p| p.relays[3]).collect();
        let mut i = 0;
        results.push(bench("flow/remove_node + repair", budget, || {
            let v = victims[i % victims.len()];
            i += 1;
            black_box(f.remove_node(v));
            f.revive_node(v, 3);
        }));
    }

    // the exact optimum (global knowledge, the paper's out-of-kilter)
    {
        let mut rng = Rng::new(4);
        let prob = random_problem(1, 40, 8, (1.0, 3.0), (1.0, 20.0), &mut rng);
        results.push(bench("mcmf/solve (40 relays, 8 stages)", budget, || {
            black_box(mcmf_min_cost(&prob));
        }));
        let mut rng = Rng::new(5);
        let big = random_problem(4, 80, 8, (1.0, 3.0), (1.0, 20.0), &mut rng);
        results.push(bench("mcmf/solve (80 relays, 4 sources)", budget, || {
            black_box(mcmf_min_cost(&big));
        }));
    }

    // annealer acceptance (innermost loop of Change/Redirect)
    {
        let mut a = Annealer::paper_default();
        let mut rng = Rng::new(6);
        results.push(bench("anneal/accept", budget, || {
            black_box(a.accept(1.0, 1.1, &mut rng));
            a.temperature = 1.7;
        }));
    }

    println!("\n# flow_bench");
    for r in &results {
        println!("{}", r.report());
    }
}
