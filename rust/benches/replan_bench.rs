//! `cargo bench --bench replan_bench` — cold re-plan vs warm-start
//! re-plan (ISSUE 1 tentpole) across churn rates 0/10/20%, plus the
//! single-crash headline case.  Writes `BENCH_flow_replan.json` at the
//! repo root; the test-sized version of the same measurement runs in
//! `rust/tests/integration.rs` on every `cargo test`.

use std::fmt::Write as _;
use std::time::Duration;

use gwtf::coordinator::GwtfRouter;
use gwtf::cost::NodeId;
use gwtf::flow::FlowParams;
use gwtf::sim::scenario::{build, ScenarioConfig};
use gwtf::util::bench::{bench, black_box};

fn main() {
    let budget = Duration::from_millis(500);
    let mut results = Vec::new();
    let mut cases = String::new();

    // --- single crash on an established plan ---
    {
        let sc = build(&ScenarioConfig::table2(true, 0.0, 31));
        let n = sc.topo.n();
        let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 31);
        let mut alive = vec![true; n];
        let (paths, _) = router.plan(&alive);
        let victim = paths[0].relays[1];
        alive[victim.0] = false;

        let mut cold = GwtfRouter::from_scenario(&sc, FlowParams::default(), 31);
        cold.plan(&vec![true; n]);
        let r_cold = bench("replan/cold (single crash)", budget, || {
            black_box(cold.plan(&alive));
        });
        let cold_rounds = cold.last_rounds;

        // `replan` keeps its warm state across calls, so repeated calls
        // measure the steady-state incremental cost.
        router.replan(&alive, &[victim]);
        let r_warm = bench("replan/warm (single crash)", budget, || {
            black_box(router.replan(&alive, &[victim]));
        });
        let warm_rounds = router.last_rounds;

        writeln!(
            cases,
            "    {{\"case\": \"single-crash\", \"cold_rounds\": {cold_rounds}, \
             \"warm_rounds\": {warm_rounds}, \"cold_mean_ms\": {:.3}, \
             \"warm_mean_ms\": {:.3}}},",
            r_cold.mean_ns / 1e6,
            r_warm.mean_ns / 1e6,
        )
        .unwrap();
        results.push(r_cold);
        results.push(r_warm);
    }

    // --- churn sweep: fresh churn sample every call ---
    for &rate in &[0.0, 0.1, 0.2] {
        let sc = build(&ScenarioConfig::table2(false, rate, 77));
        let n = sc.topo.n();

        let mut cold = GwtfRouter::from_scenario(&sc, FlowParams::default(), 7);
        let mut cold_churn = sc.churn.clone();
        cold.plan(&vec![true; n]);
        let mut cold_rounds = 0usize;
        let mut cold_calls = 0usize;
        let r_cold = bench(&format!("replan/cold (churn {:.0}%)", rate * 100.0), budget, || {
            let ev = cold_churn.sample_iteration();
            let alive = cold_churn.planning_view(&ev);
            black_box(cold.plan(&alive));
        });
        // count rounds over a deterministic pass for the JSON record
        {
            let mut r = GwtfRouter::from_scenario(&sc, FlowParams::default(), 7);
            let mut churn = sc.churn.clone();
            r.plan(&vec![true; n]);
            for _ in 0..6 {
                let ev = churn.sample_iteration();
                let alive = churn.planning_view(&ev);
                r.plan(&alive);
                cold_rounds += r.last_rounds;
                cold_calls += 1;
            }
        }

        let mut warm = GwtfRouter::from_scenario(&sc, FlowParams::default(), 7);
        let mut warm_churn = sc.churn.clone();
        let mut prev = vec![true; n];
        warm.plan(&prev);
        let r_warm = bench(&format!("replan/warm (churn {:.0}%)", rate * 100.0), budget, || {
            let ev = warm_churn.sample_iteration();
            let alive = warm_churn.planning_view(&ev);
            let dirty: Vec<NodeId> =
                (0..n).filter(|&i| prev[i] && !alive[i]).map(NodeId).collect();
            black_box(warm.replan(&alive, &dirty));
            prev = alive;
        });
        let mut warm_rounds = 0usize;
        {
            let mut r = GwtfRouter::from_scenario(&sc, FlowParams::default(), 7);
            let mut churn = sc.churn.clone();
            let mut prev = vec![true; n];
            r.plan(&prev);
            for _ in 0..6 {
                let ev = churn.sample_iteration();
                let alive = churn.planning_view(&ev);
                let dirty: Vec<NodeId> =
                    (0..n).filter(|&i| prev[i] && !alive[i]).map(NodeId).collect();
                r.replan(&alive, &dirty);
                warm_rounds += r.last_rounds;
                prev = alive;
            }
        }

        writeln!(
            cases,
            "    {{\"churn\": {rate}, \"iters\": {cold_calls}, \"cold_rounds\": {cold_rounds}, \
             \"warm_rounds\": {warm_rounds}, \"cold_mean_ms\": {:.3}, \
             \"warm_mean_ms\": {:.3}}},",
            r_cold.mean_ns / 1e6,
            r_warm.mean_ns / 1e6,
        )
        .unwrap();
        results.push(r_cold);
        results.push(r_warm);
    }

    println!("\n# replan_bench");
    for r in &results {
        println!("{}", r.report());
    }

    let cases = cases.trim_end().trim_end_matches(',').to_string();
    let json = format!(
        "{{\n  \"bench\": \"flow_replan\",\n  \"scenario\": \"table2, 18 nodes, 6 stages\",\n  \
         \"source\": \"rust/benches/replan_bench.rs\",\n  \"cases\": [\n{cases}\n  ]\n}}\n"
    );
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_flow_replan.json");
    std::fs::write(path, &json).unwrap();
    println!("\nwrote {path}");
}
