//! `cargo bench --bench runtime_bench` — PJRT runtime latency: compile
//! (once) and per-call execution of the AOT stage functions, plus a full
//! real microbatch forward+backward (EXPERIMENTS.md §Perf).
//!
//! Requires `make artifacts`; skips gracefully otherwise.

use std::sync::Arc;
use std::time::Duration;

use gwtf::data::{BatchIterator, CorpusConfig, SyntheticCorpus};
use gwtf::runtime::{BlockStage, DataNodeModel, Manifest, Runtime};
use gwtf::util::bench::{bench, black_box};

fn main() -> anyhow::Result<()> {
    let manifest = match Manifest::load(Manifest::default_dir()) {
        Ok(m) => m,
        Err(e) => {
            println!("# runtime_bench skipped: {e}");
            return Ok(());
        }
    };
    let fam = manifest.family("llama")?.clone();
    let cfg = fam.config.clone();
    let rt = Arc::new(Runtime::cpu()?);

    // compile every artifact once, timing the cold compiles
    let t0 = std::time::Instant::now();
    for entry in fam.entries.values() {
        rt.load(entry)?;
    }
    let stats = rt.stats();
    println!(
        "# compile: {} executables in {:.2}s ({:.0} ms avg)",
        stats.compiles,
        t0.elapsed().as_secs_f64(),
        1000.0 * stats.compile_s / stats.compiles.max(1) as f64
    );

    let mut results = Vec::new();
    let budget = Duration::from_millis(1000);

    let data_node = DataNodeModel::init(rt.clone(), &fam, 1)?;
    let stage = BlockStage::init(rt.clone(), &fam, 0, 2)?;
    let corpus = SyntheticCorpus::generate(&CorpusConfig {
        vocab_size: cfg.vocab_size,
        length: 1 << 14,
        seed: 5,
        ..Default::default()
    });
    let mut batches = BatchIterator::new(corpus, cfg.microbatch, cfg.seq_len);
    let batch = batches.next_batch();
    let x = data_node.embed(&batch.tokens)?;

    results.push(bench("runtime/embed_fwd", budget, || {
        black_box(data_node.embed(&batch.tokens).unwrap());
    }));
    results.push(bench("runtime/stage_fwd (2 blocks)", budget, || {
        black_box(stage.forward(&x).unwrap());
    }));
    let dy = x.clone();
    results.push(bench("runtime/stage_bwd (remat)", budget, || {
        black_box(stage.backward(&x, &dy).unwrap());
    }));
    results.push(bench("runtime/head_bwd (loss+grad)", budget, || {
        black_box(data_node.head_backward(&x, &batch.targets).unwrap());
    }));

    // one full microbatch through all stages, fwd+bwd
    {
        let mut stages = Vec::new();
        for s in 0..cfg.n_stages {
            stages.push(BlockStage::init(rt.clone(), &fam, s, 10 + s as u32)?);
        }
        results.push(bench(
            &format!("runtime/microbatch fwd+bwd ({} stages)", cfg.n_stages),
            Duration::from_millis(2000),
            || {
                let mut acts = vec![data_node.embed(&batch.tokens).unwrap()];
                for s in 0..stages.len() {
                    let y = stages[s].forward(&acts[s]).unwrap();
                    acts.push(y);
                }
                let (_, mut dy, _) =
                    data_node.head_backward(acts.last().unwrap(), &batch.targets).unwrap();
                for s in (0..stages.len()).rev() {
                    let (_, dx) = stages[s].backward(&acts[s], &dy).unwrap();
                    dy = dx;
                }
                black_box(dy);
            },
        ));
    }

    println!("\n# runtime_bench (microbatch {} x seq {} x d_model {})", cfg.microbatch, cfg.seq_len, cfg.d_model);
    for r in &results {
        println!("{}", r.report());
    }
    let s = rt.stats();
    println!(
        "\ntotal: {} executions, {:.1} ms avg",
        s.executions,
        1000.0 * s.execute_s / s.executions.max(1) as f64
    );
    Ok(())
}
