//! `cargo bench --bench sim_bench` — end-to-end iteration simulation
//! latency (the experiment harness's own hot path) plus router planning
//! costs for all three systems (EXPERIMENTS.md §Perf).

use std::sync::Arc;
use std::time::Duration;

use gwtf::baselines::{DtfmRouter, GaParams, SwarmRouter};
use gwtf::coordinator::GwtfRouter;
use gwtf::flow::FlowParams;
use gwtf::sim::scenario::{build, ScenarioConfig};
use gwtf::sim::training::{BlockingPlanner, TrainingSim};
use gwtf::util::bench::{bench, black_box};
use gwtf::util::Rng;

fn main() {
    let budget = Duration::from_millis(400);
    let mut results = Vec::new();

    let sc = build(&ScenarioConfig::table2(false, 0.1, 7));

    // one full simulated iteration (plan + events + recovery + aggregation)
    {
        let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 7);
        let mut sim = TrainingSim::new(sc.topo.clone(), sc.sim_cfg);
        let mut churn = sc.churn.clone();
        let mut rng = Rng::new(9);
        results.push(bench("sim/iteration (gwtf, 18 nodes, 10% churn)", budget, || {
            let ev = churn.sample_iteration();
            let alive = churn.planning_view(&ev);
            let (paths, planning) = router.plan(&alive);
            black_box(sim.run_iteration(&sc.prob, &mut router, &ev, &churn, planning, paths, &mut rng));
        }));
    }

    // router planning in isolation
    {
        let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 8);
        let alive = vec![true; sc.topo.n()];
        results.push(bench("plan/gwtf (18 nodes, 6 stages)", budget, || {
            black_box(router.plan(&alive));
        }));
    }
    // warm-start incremental replan after one crash (steady state)
    {
        let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 8);
        let mut alive = vec![true; sc.topo.n()];
        let (paths, _) = router.plan(&alive);
        let victim = paths[0].relays[1];
        alive[victim.0] = false;
        results.push(bench("replan/gwtf warm (1 crash)", budget, || {
            black_box(router.replan(&alive, &[victim]));
        }));
    }
    {
        let topo = sc.topo.clone();
        let payload = sc.sim_cfg.payload_bytes;
        let mut router = SwarmRouter::from_problem(
            &sc.prob,
            Arc::new(move |i, j| topo.cost(i, j, payload)),
            8,
        );
        let alive = vec![true; sc.topo.n()];
        results.push(bench("plan/swarm greedy", budget, || {
            black_box(router.plan_once(&alive));
        }));
    }
    {
        let sc6 = build(&ScenarioConfig::table6(9));
        let topo = sc6.topo.clone();
        let payload = sc6.sim_cfg.payload_bytes;
        let cost: gwtf::baselines::CostFn = Arc::new(move |i, j| topo.cost(i, j, payload));
        let mut n = 0u64;
        results.push(bench("plan/dtfm genetic (full GA)", Duration::from_millis(1500), || {
            n += 1;
            let mut router = DtfmRouter::new(
                sc6.prob.graph.clone(),
                sc6.prob.demand.clone(),
                cost.clone(),
                GaParams { generations: 50, ..Default::default() },
                n,
            );
            let alive = vec![true; sc6.topo.n()];
            black_box(router.plan_once(&alive));
        }));
    }

    // churn sampling + topology generation (setup costs)
    {
        let mut churn = sc.churn.clone();
        results.push(bench("churn/sample_iteration", budget, || {
            black_box(churn.sample_iteration());
        }));
        let mut seed = 0;
        results.push(bench("topology/generate (18 nodes)", budget, || {
            seed += 1;
            let mut rng = Rng::new(seed);
            black_box(gwtf::net::Topology::generate(
                &gwtf::net::TopologyConfig::default(),
                &mut rng,
            ));
        }));
    }

    println!("\n# sim_bench");
    for r in &results {
        println!("{}", r.report());
    }
}
