//! `cargo bench --bench table_bench` — regenerates Tables II, III and VI.
//!
//! Writes `bench_results/table{2,3,6}.{md,csv}` and prints the paper-style
//! rows.  Repetitions default to a CI-friendly count; set
//! `GWTF_BENCH_REPS=25` (the paper's number) for the full run, or use
//! `gwtf bench table2 --reps 25`.

use gwtf::experiments::{results_dir, run_table2, run_table3, run_table6, TableOpts};

fn reps() -> usize {
    std::env::var("GWTF_BENCH_REPS").ok().and_then(|s| s.parse().ok()).unwrap_or(10)
}

fn main() -> anyhow::Result<()> {
    let opts = TableOpts { reps: reps(), iters_per_rep: 4, seed: 1, ..Default::default() };
    let dir = results_dir();
    println!("# table_bench: {} repetitions x {} iterations\n", opts.reps, opts.iters_per_rep);

    for (name, run) in [
        ("table2", run_table2 as fn(&TableOpts) -> anyhow::Result<gwtf::metrics::MetricsTable>),
        ("table3", run_table3),
        ("table6", run_table6),
    ] {
        let t0 = std::time::Instant::now();
        let table = run(&opts)?;
        table.write(&dir, name)?;
        println!("{}", table.to_markdown());
        println!("[{name}] regenerated in {:.1}s -> {}/{name}.md\n", t0.elapsed().as_secs_f64(), dir.display());
    }

    // Ablation: GWTF forced to SWARM-style full-restart recovery shows the
    // value of §V-D path repair (DESIGN.md §7).
    let ablation = TableOpts {
        reps: (reps() / 2).max(3),
        gwtf_restart_recovery: true,
        ..opts.clone()
    };
    let t = run_table2(&ablation)?;
    t.write(&dir, "table2_ablation_restart")?;
    println!("[ablation: gwtf w/ restart recovery] -> {}/table2_ablation_restart.md", dir.display());
    Ok(())
}
