//! `cargo bench --bench scale_bench` — the full-size scale sweep
//! (ISSUE 3 tentpole, extended by ISSUE 6): 100 and 200 relays across
//! 10 regions under 20% Poisson churn, gossip-overlay GWTF (warm
//! re-plans over bounded neighbor views) vs SWARM vs DT-FM, plus the
//! GWTF-only 1000-relay raw-speed case.  Writes the `full` profile of
//! `BENCH_scale.json` at the repo root; the test-sized version of the
//! same measurement runs in `rust/tests/scale_guard.rs` on every
//! `cargo test` and gates planner-round regressions in CI.
//!
//! After the sweep a planner-only microbench times the cold flow plan
//! (no engine, no baselines) at 100/200/1000 relays with 1 worker
//! thread vs the machine's parallelism — plans are bit-identical at
//! any thread count, so the rounds column must not move between the
//! two, only the wall clock.

use std::time::Instant;

use gwtf::coordinator::GwtfRouter;
use gwtf::experiments::{run_scale, scale_json_path, update_scale_json, ScaleOpts};
use gwtf::flow::FlowParams;
use gwtf::sim::scenario::{build, ScenarioConfig};

fn planner_microbench(n_threads: usize) {
    println!("\n# planner-only microbench — cold plan, threads 1 vs {n_threads}");
    for &relays in &[100usize, 200, 1000] {
        let sc = build(&ScenarioConfig::scale(relays, 0.2, 7));
        let alive = vec![true; sc.topo.n()];
        print!("{relays:>5} relays:");
        for &threads in &[1usize, n_threads] {
            let params = FlowParams { threads, ..FlowParams::default() };
            let mut router = GwtfRouter::from_scenario(&sc, params, 7 ^ 0xA);
            let t0 = Instant::now();
            let (paths, _) = router.plan(&alive);
            let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
            assert!(!paths.is_empty(), "cold plan routed nothing");
            print!(
                "  [t={threads}] {} rounds / {:>7.1} ms = {:>6.1} rounds/s",
                router.last_rounds,
                wall_s * 1e3,
                router.last_rounds as f64 / wall_s,
            );
        }
        println!();
    }
}

fn main() {
    let n_threads = std::thread::available_parallelism().map_or(4, |p| p.get().min(8));
    let opts = ScaleOpts { planner_threads: n_threads, ..ScaleOpts::default() };
    let (table, report) = run_scale(&opts).expect("scale sweep");
    println!("{}", table.to_markdown());
    for c in &report.cases {
        println!(
            "{:>5} relays {:<6} plans {:>3}  rounds {:>5} (cold {:>4})  wall {:>9.1} ms  \
             completed {:>6}  events {:>8} ({:>9.0} ev/s)  links {:>8}  edges {:>8}  \
             rss {:>7.1} MiB",
            c.relays,
            c.system,
            c.plan_calls,
            c.plan_rounds_total,
            c.cold_rounds,
            c.plan_wall_ms,
            c.throughput_total,
            c.events_total,
            c.events_per_sec(),
            c.resident_link_entries,
            c.resident_cache_entries,
            c.peak_rss_mib,
        );
    }
    let path = scale_json_path();
    update_scale_json(&path, "full", &report).expect("write BENCH_scale.json");
    println!("\nwrote {}", path.display());

    planner_microbench(n_threads);
}
