//! `cargo bench --bench scale_bench` — the full-size scale sweep
//! (ISSUE 3 tentpole): 100 and 200 relays across 10 regions under 20%
//! Poisson churn, gossip-overlay GWTF (warm re-plans over bounded
//! neighbor views) vs SWARM vs DT-FM.  Writes the `full` profile of
//! `BENCH_scale.json` at the repo root; the test-sized version of the
//! same measurement runs in `rust/tests/scale_guard.rs` on every
//! `cargo test` and gates planner-round regressions in CI.

use gwtf::experiments::{run_scale, scale_json_path, update_scale_json, ScaleOpts};

fn main() {
    let opts = ScaleOpts::default();
    let (table, report) = run_scale(&opts).expect("scale sweep");
    println!("{}", table.to_markdown());
    for c in &report.cases {
        println!(
            "{:>5} relays {:<6} plans {:>3}  rounds {:>5} (cold {:>4})  wall {:>9.1} ms  \
             completed {:>6}",
            c.relays,
            c.system,
            c.plan_calls,
            c.plan_rounds_total,
            c.cold_rounds,
            c.plan_wall_ms,
            c.throughput_total,
        );
    }
    let path = scale_json_path();
    update_scale_json(&path, "full", &report).expect("write BENCH_scale.json");
    println!("\nwrote {}", path.display());
}
