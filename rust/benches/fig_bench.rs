//! `cargo bench --bench fig_bench` — regenerates Figures 5, 6 and 7.
//!
//! - Fig. 5: node-addition improvement, 4 policies x 5 Table IV settings.
//! - Fig. 7: flow tests 1–6, GWTF vs SWARM-greedy vs optimal.
//! - Fig. 6: loss convergence (only when `make artifacts` has run; a short
//!   run here — the full curve comes from `examples/churn_train`).

use gwtf::experiments::{results_dir, run_fig5, run_fig6, run_fig7, Fig6Opts};

fn main() -> anyhow::Result<()> {
    let dir = results_dir();
    let runs: usize =
        std::env::var("GWTF_BENCH_RUNS").ok().and_then(|s| s.parse().ok()).unwrap_or(5);

    let t0 = std::time::Instant::now();
    let fig5 = run_fig5(runs, 11, false)?;
    fig5.write(&dir, "fig5")?;
    println!("# Fig. 5 — improvement per Table IV setting (higher = better)");
    println!("{}", gwtf::experiments::fig5_summary(&fig5));
    println!("[fig5] {} runs in {:.1}s -> {}/fig5.csv\n", runs, t0.elapsed().as_secs_f64(), dir.display());

    let t0 = std::time::Instant::now();
    let fig7 = run_fig7(runs, 17)?;
    fig7.write(&dir, "fig7")?;
    // print final-cost comparison per test
    println!("# Fig. 7 final avg cost per microbatch");
    for t in 1..=6 {
        let g = fig7.series[&format!("t{t}_gwtf_final")].last().unwrap().1;
        let s = fig7.series[&format!("t{t}_swarm_final")].last().unwrap().1;
        let o = fig7
            .series
            .get(&format!("t{t}_optimal_final"))
            .map(|v| format!("{:.1}", v.last().unwrap().1))
            .unwrap_or_else(|| "-".into());
        println!("test {t}: gwtf {g:.1}  swarm {s:.1}  optimal {o}");
    }
    println!("[fig7] {} reps in {:.1}s -> {}/fig7.csv\n", runs, t0.elapsed().as_secs_f64(), dir.display());

    // Fig. 6 needs artifacts; skip gracefully if they are not built.
    match gwtf::runtime::Manifest::load(gwtf::runtime::Manifest::default_dir()) {
        Ok(_) => {
            let t0 = std::time::Instant::now();
            let opts = Fig6Opts { steps: 8, microbatches_per_step: 2, ..Default::default() };
            let (fig6, max_delta) = run_fig6(&opts)?;
            fig6.write(&dir, "fig6_short")?;
            println!("# Fig. 6 (short run; full curve: examples/churn_train)");
            println!("max |loss(gwtf) - loss(centralized)| = {max_delta:.2e}");
            println!("[fig6] {} steps in {:.1}s -> {}/fig6_short.csv", opts.steps, t0.elapsed().as_secs_f64(), dir.display());
        }
        Err(_) => println!("[fig6] skipped: run `make artifacts` first"),
    }
    Ok(())
}
