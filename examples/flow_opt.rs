//! Flow optimization deep-dive: watch Request Flow / Change / Redirect
//! converge (paper §V-A/§V-C, Fig. 7's x-axis) and compare the final cost
//! against the SWARM greedy baseline and the exact optimum.
//!
//! ```bash
//! cargo run --release --example flow_opt [seed]
//! ```

use std::sync::Arc;

use gwtf::baselines::{CostFn, SwarmRouter};
use gwtf::flow::decentralized::{DecentralizedFlow, FlowParams};
use gwtf::flow::graph::random_problem;
use gwtf::flow::mcmf::mcmf_min_cost;
use gwtf::sim::training::BlockingPlanner;
use gwtf::util::Rng;

fn main() {
    let seed: u64 =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(17);

    // Table V test 1: one source, 40 relays over 8 stages, caps U(1,3).
    let mut rng = Rng::new(seed);
    let prob = random_problem(1, 40, 8, (1.0, 3.0), (1.0, 20.0), &mut rng);
    println!(
        "flow test: 1 source x {} microbatches, 40 relays, 8 stages",
        prob.demand[0]
    );

    // GWTF: sum-cost objective (the Fig. 7 configuration).
    let params = FlowParams { minmax_objective: false, ..FlowParams::default() };
    let mut f = DecentralizedFlow::new(&prob, params, seed);
    println!("\nround  complete  avg_cost/mb  moves");
    let mut shown = 0;
    for _ in 0..120 {
        let s = f.step();
        // print the interesting rounds: first 5, then every 20th
        if s.round <= 5 || s.round % 20 == 0 || (s.moves_applied > 0 && shown < 20) {
            println!(
                "{:>5}  {:>8}  {:>11.2}  {:>5}",
                s.round,
                s.complete_flows,
                if s.avg_cost_per_microbatch.is_finite() { s.avg_cost_per_microbatch } else { f64::NAN },
                s.moves_applied
            );
            shown += 1;
        }
        if s.moves_applied == 0 && s.round > 20 {
            println!("steady state at round {}", s.round);
            break;
        }
    }
    let gwtf_avg = f.total_cost() / f.complete_flows().max(1) as f64;

    // SWARM greedy baseline on the same instance (capacity-aware for the
    // abstract cost comparison — see experiments::figures::run_fig7).
    let mut rng2 = Rng::new(seed);
    let prob2 = random_problem(1, 40, 8, (1.0, 3.0), (1.0, 20.0), &mut rng2);
    let cost: CostFn = Arc::new(move |i, j| prob2.cost(i, j));
    let mut swarm = SwarmRouter::from_problem(&prob, cost, seed);
    swarm.ignore_capacity = false;
    let alive = vec![true; prob.cap.len()];
    let (paths, _) = swarm.plan_once(&alive);
    let swarm_avg = swarm.total_cost(&paths) / paths.len().max(1) as f64;

    // Exact optimum (requires global knowledge).
    let opt = mcmf_min_cost(&prob);

    println!("\n=== final average cost per microbatch ===");
    println!("gwtf (decentralized) : {gwtf_avg:.2}");
    println!("swarm (greedy)       : {swarm_avg:.2}");
    println!("optimal (global)     : {:.2}", opt.avg_cost_per_microbatch());
    println!(
        "gwtf is {:.0}% above optimal, {:.0}% below swarm",
        (gwtf_avg / opt.avg_cost_per_microbatch() - 1.0) * 100.0,
        (1.0 - gwtf_avg / swarm_avg) * 100.0
    );

    // Crash tolerance: kill a used relay and watch the flow repair itself.
    let victim = f.established_paths()[0].relays[3];
    let (repaired, destroyed) = f.remove_node(victim);
    println!("\ncrashed {victim}: {repaired} flows repaired in place, {destroyed} destroyed");
}
