//! Quickstart: the GWTF public API in five minutes.
//!
//! Builds the paper's Table II scenario (18 geo-distributed nodes, 6
//! pipeline stages, 2 data nodes), routes microbatch flows with the
//! decentralized optimizer, simulates a few training iterations under 10%
//! churn, and prints the same metrics the paper reports.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use gwtf::coordinator::GwtfRouter;
use gwtf::flow::mcmf::mcmf_min_cost;
use gwtf::flow::FlowParams;
use gwtf::sim::scenario::{build, ScenarioConfig};
use gwtf::sim::training::TrainingSim;
use gwtf::util::Rng;

fn main() {
    // 1. A scenario: topology, stage assignment, capacities, churn process.
    let cfg = ScenarioConfig::table2(/*homogeneous=*/ false, /*churn=*/ 0.1, /*seed=*/ 7);
    let sc = build(&cfg);
    println!(
        "scenario: {} data nodes, {} relays, {} stages, payload {:.0} MB",
        sc.data_nodes.len(),
        sc.relays.len(),
        sc.prob.graph.n_stages(),
        sc.sim_cfg.payload_bytes / 1e6
    );

    // 2. The decentralized flow optimizer vs the global optimum.
    let mut router = GwtfRouter::from_scenario(&sc, FlowParams::default(), 7);
    let alive = vec![true; sc.topo.n()];
    let (paths, planning_s) = router.plan(&alive);
    let opt = mcmf_min_cost(&sc.prob);
    println!(
        "routed {} flows in {} protocol rounds ({planning_s:.1}s ctrl); optimal routes {}",
        paths.len(),
        router.last_rounds,
        opt.flow
    );
    for (i, p) in paths.iter().take(2).enumerate() {
        println!("  flow {i}: {} -> {:?} -> {}", p.source, p.relays, p.source);
    }

    // 3. Simulated training iterations under churn.
    let mut sim = TrainingSim::new(sc.topo.clone(), sc.sim_cfg.clone());
    let mut churn = sc.churn.clone();
    let mut rng = Rng::new(99);
    println!("\niter  makespan_s  done  fwd_rec  bwd_rec  wasted_gpu_s");
    for i in 0..5 {
        let events = churn.sample_iteration();
        let alive = churn.planning_view(&events);
        let (paths, planning) = router.plan(&alive);
        let m = sim.run_iteration(&sc.prob, &mut router, &events, &churn, planning, paths, &mut rng);
        println!(
            "{i:>4}  {:>10.1}  {:>4}  {:>7}  {:>7}  {:>12.1}",
            m.makespan_s, m.completed, m.fwd_recoveries, m.bwd_recoveries, m.wasted_gpu_s
        );
    }

    println!("\nnext: cargo run --release --example churn_train   (real model, real gradients)");
}
