//! End-to-end driver: real decentralized training with churn (Fig. 6).
//!
//! Proves all three layers compose: the Rust coordinator routes and
//! recovers flows over the simulated volunteer network while the actual
//! transformer stages (JAX/Pallas, AOT-compiled to HLO) execute forward,
//! backward and SGD updates through PJRT.  The same batches are also fed
//! to a centralized baseline; the paper's convergence claim (§VI) is that
//! the two loss curves match — here they match exactly, because GWTF's
//! routing never changes the math, only the schedule.
//!
//! ```bash
//! make artifacts          # once
//! cargo run --release --example churn_train -- --steps 60 --churn 0.1
//! ```

use gwtf::config::Args;
use gwtf::experiments::{run_fig6, Fig6Opts};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let opts = Fig6Opts {
        steps: args.usize_or("steps", 60)?,
        microbatches_per_step: args.usize_or("microbatches", 4)?,
        lr: args.f64_or("lr", 0.25)? as f32,
        churn_p: args.f64_or("churn", 0.1)?,
        family: args.str_or("family", "llama"),
        seed: args.u64_or("seed", 42)?,
        ..Default::default()
    };
    println!(
        "# churn_train: {} | {} steps x {} microbatches | churn {:.0}% | lr {}",
        opts.family,
        opts.steps,
        opts.microbatches_per_step,
        opts.churn_p * 100.0,
        opts.lr
    );

    let t0 = std::time::Instant::now();
    let (report, max_delta) = run_fig6(&opts)?;
    let wall = t0.elapsed().as_secs_f64();

    // loss curve (both runs) every few steps
    let central = &report.series["centralized"];
    let gwtf = &report.series["gwtf_churn"];
    let mks = &report.series["gwtf_sim_makespan_s"];
    println!("\n{:>5} {:>12} {:>12} {:>14}", "step", "centralized", "gwtf_churn", "sim_makespan_s");
    let stride = (opts.steps / 15).max(1);
    for i in (0..central.len()).step_by(stride) {
        println!(
            "{:>5} {:>12.4} {:>12.4} {:>14.1}",
            central[i].0, central[i].1, gwtf[i].1, mks[i].1
        );
    }
    let first = central.first().unwrap().1;
    let last = central.last().unwrap().1;
    println!("\nloss: {first:.4} -> {last:.4} over {} steps ({wall:.0}s wall)", central.len());
    println!("max |loss(gwtf) - loss(centralized)| = {max_delta:.2e}");
    assert!(max_delta < 1e-5, "GWTF must converge identically to centralized SGD");
    assert!(last < first, "loss must decrease");

    let dir = gwtf::experiments::results_dir();
    report.write(&dir, "fig6")?;
    println!("wrote {}/fig6.csv", dir.display());
    Ok(())
}
