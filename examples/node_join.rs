//! Node joining walkthrough (paper §V-B, Fig. 3 and Fig. 5).
//!
//! Shows the leader's utilization-ranked placement expanding the
//! bottleneck stage, then runs the Fig. 5 comparison on one Table IV
//! setting: GWTF vs highest-capacity-first vs random vs the exhaustive
//! optimal.
//!
//! ```bash
//! cargo run --release --example node_join -- [--setting 1] [--runs 5]
//! ```

use gwtf::baselines::join_eval::{compare_policies, JoinExperiment, JoinPolicyExt, JoinSetting};
use gwtf::config::Args;
use gwtf::util::Summary;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let si = args.usize_or("setting", 1)?;
    let runs = args.usize_or("runs", 5)?;
    let seed = args.u64_or("seed", 11)?;
    let setting = if args.flag("full") {
        JoinSetting::setting(si)
    } else {
        JoinSetting::setting(si).reduced()
    };
    println!("# node_join — Table IV setting {}", setting.name);

    // --- Fig. 3-style single walkthrough ---
    let exp = JoinExperiment::generate(&setting, seed);
    let prob = exp.problem();
    println!("\ninitial stage capacities (bottleneck first expands):");
    for s in 0..prob.graph.n_stages() {
        println!("  stage {s}: {}", prob.stage_capacity(s));
    }
    println!("pending candidates: {:?}", exp.pending);
    let outcome = exp.run(JoinPolicyExt::Gwtf);
    println!(
        "gwtf placement: cost {:.0} -> {:.0} (improvement {:.1}%)",
        outcome.cost_before,
        outcome.cost_after,
        outcome.improvement() * 100.0
    );
    println!("cost trace: {:?}", outcome.trace.iter().map(|c| *c as i64).collect::<Vec<_>>());

    // --- Fig. 5 comparison over several seeds ---
    println!("\n# Fig. 5 policies over {runs} runs (improvement, higher = better)");
    let mut per: std::collections::BTreeMap<&str, Vec<f64>> = Default::default();
    for r in 0..runs {
        for (name, o) in compare_policies(&setting, seed + 31 * r as u64) {
            per.entry(name).or_default().push(o.improvement());
        }
    }
    let mut rows: Vec<(&str, Summary)> =
        per.into_iter().map(|(n, xs)| (n, Summary::of(&xs))).collect();
    rows.sort_by(|a, b| b.1.mean.partial_cmp(&a.1.mean).unwrap());
    for (name, s) in &rows {
        let bars = (s.mean * 200.0).max(0.0) as usize;
        println!("{name:<16} {:>7.2}% ± {:>5.2}%  {}", s.mean * 100.0, s.std * 100.0, "#".repeat(bars));
    }
    // The paper's ordering: optimal > gwtf > capacity-first > random.
    let names: Vec<&str> = rows.iter().map(|r| r.0).collect();
    println!(
        "\nordering: {} {}",
        names.join(" > "),
        if names.first() == Some(&"optimal") { "(matches Fig. 5)" } else { "" }
    );
    Ok(())
}
