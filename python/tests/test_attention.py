"""L1 correctness: Pallas fused attention vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes (as required for the kernel layer); a few
pinned cases cover the block-boundary edge cases explicitly.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import (
    flash_attention,
    mxu_utilization_estimate,
    pick_block,
    vmem_footprint_bytes,
)
from compile.kernels import ref


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def _check(B, H, S, D, causal, dtype=jnp.float32, tol=2e-5):
    k = jax.random.PRNGKey(B * 1000 + H * 100 + S + D)
    q = _rand(jax.random.fold_in(k, 0), (B, H, S, D), dtype)
    kk = _rand(jax.random.fold_in(k, 1), (B, H, S, D), dtype)
    v = _rand(jax.random.fold_in(k, 2), (B, H, S, D), dtype)
    out = flash_attention(q, kk, v, causal=causal)
    exp = ref.attention_ref(q, kk, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=tol, rtol=tol)


class TestPinnedShapes:
    def test_single_block(self):
        _check(1, 1, 16, 8, causal=True)

    def test_multi_qblock(self):
        _check(2, 2, 256, 32, causal=True)

    def test_non_causal(self):
        _check(2, 2, 256, 32, causal=False)

    def test_prime_seq(self):
        # seq=31 forces pick_block to fall back to a divisor (1 here is
        # avoided: 31 is prime so block=31 <= 128 stays whole).
        _check(1, 2, 31, 16, causal=True)

    def test_seq_odd_divisor(self):
        _check(1, 1, 96, 16, causal=True)  # block_q=96

    def test_block_larger_than_preferred(self):
        _check(1, 1, 384, 16, causal=True)  # 384 = 3*128

    def test_head_dim_one(self):
        _check(1, 1, 64, 1, causal=True)

    def test_bf16_inputs(self):
        _check(1, 2, 64, 16, causal=True, dtype=jnp.bfloat16, tol=3e-2)

    def test_matches_under_jit(self):
        B, H, S, D = 2, 2, 64, 16
        k = jax.random.PRNGKey(0)
        q, kk, v = (_rand(jax.random.fold_in(k, i), (B, H, S, D), jnp.float32) for i in range(3))
        out = jax.jit(lambda a, b, c: flash_attention(a, b, c, causal=True))(q, kk, v)
        exp = ref.attention_ref(q, kk, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5, rtol=2e-5)

    def test_large_magnitude_stability(self):
        """Online softmax must not overflow for large logits."""
        B, H, S, D = 1, 1, 64, 16
        q = jnp.full((B, H, S, D), 30.0, jnp.float32)
        k = jnp.full((B, H, S, D), 30.0, jnp.float32)
        v = jax.random.normal(jax.random.PRNGKey(0), (B, H, S, D), jnp.float32)
        out = flash_attention(q, k, v, causal=True)
        assert bool(jnp.all(jnp.isfinite(out)))


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    s=st.sampled_from([8, 16, 24, 32, 48, 64, 96, 128, 160]),
    d=st.sampled_from([4, 8, 16, 32]),
    causal=st.booleans(),
)
def test_attention_hypothesis_sweep(b, h, s, d, causal):
    _check(b, h, s, d, causal)


@settings(max_examples=10, deadline=None)
@given(
    s=st.sampled_from([16, 32, 64, 128]),
    d=st.sampled_from([8, 16, 32]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_attention_dtype_sweep(s, d, dtype):
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    _check(1, 2, s, d, True, dtype=dtype, tol=tol)


class TestBlockPicking:
    def test_pick_block_divides(self):
        for s in range(1, 400):
            b = pick_block(s, 128)
            assert s % b == 0 and 1 <= b <= min(128, s)

    def test_pick_block_prefers_large(self):
        assert pick_block(256, 128) == 128
        assert pick_block(128, 128) == 128
        assert pick_block(96, 128) == 96

    def test_vmem_footprint_positive_and_bounded(self):
        fp = vmem_footprint_bytes(2048, 128)
        assert 0 < fp <= 16 * 1024 * 1024  # fits VMEM

    def test_mxu_estimate_range(self):
        for s, d in [(128, 128), (64, 32), (2048, 64)]:
            u = mxu_utilization_estimate(s, d)
            assert 0.0 < u <= 1.0
        assert mxu_utilization_estimate(2048, 128) == 1.0
