"""L1 correctness: fused (norm + MLP) Pallas kernels vs jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.fused_mlp import (
    fused_gelu_mlp,
    fused_swiglu_mlp,
    mlp_vmem_footprint_bytes,
)
from compile.kernels import ref


def _mk(key, shape, scale=0.1):
    return jax.random.normal(key, shape, jnp.float32) * scale


def _swiglu_inputs(rows, d, f, seed=0):
    k = jax.random.PRNGKey(seed)
    return (
        _mk(jax.random.fold_in(k, 0), (rows, d), 1.0),
        jnp.ones((d,), jnp.float32) + _mk(jax.random.fold_in(k, 1), (d,)),
        _mk(jax.random.fold_in(k, 2), (d, f)),
        _mk(jax.random.fold_in(k, 3), (d, f)),
        _mk(jax.random.fold_in(k, 4), (f, d)),
    )


def _gelu_inputs(rows, d, f, seed=0):
    k = jax.random.PRNGKey(seed)
    return (
        _mk(jax.random.fold_in(k, 0), (rows, d), 1.0),
        jnp.ones((d,), jnp.float32),
        _mk(jax.random.fold_in(k, 1), (d,)),
        _mk(jax.random.fold_in(k, 2), (d, f)),
        _mk(jax.random.fold_in(k, 3), (f,)),
        _mk(jax.random.fold_in(k, 4), (f, d)),
        _mk(jax.random.fold_in(k, 5), (d,)),
    )


class TestSwiGLU:
    def test_single_row_block(self):
        args = _swiglu_inputs(16, 32, 96)
        np.testing.assert_allclose(
            np.asarray(fused_swiglu_mlp(*args)),
            np.asarray(ref.swiglu_mlp_ref(*args)),
            atol=1e-5, rtol=1e-5,
        )

    def test_multi_row_blocks(self):
        args = _swiglu_inputs(512, 64, 160)
        np.testing.assert_allclose(
            np.asarray(fused_swiglu_mlp(*args)),
            np.asarray(ref.swiglu_mlp_ref(*args)),
            atol=1e-5, rtol=1e-5,
        )

    def test_under_jit(self):
        args = _swiglu_inputs(128, 32, 64)
        out = jax.jit(fused_swiglu_mlp)(*args)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref.swiglu_mlp_ref(*args)), atol=1e-5, rtol=1e-5
        )


class TestGeluMLP:
    def test_basic(self):
        args = _gelu_inputs(96, 48, 192)
        np.testing.assert_allclose(
            np.asarray(fused_gelu_mlp(*args)),
            np.asarray(ref.gelu_mlp_ref(*args)),
            atol=1e-5, rtol=1e-5,
        )

    def test_row_not_multiple_of_block(self):
        args = _gelu_inputs(100, 32, 64)  # pick_block falls back to 100
        np.testing.assert_allclose(
            np.asarray(fused_gelu_mlp(*args)),
            np.asarray(ref.gelu_mlp_ref(*args)),
            atol=1e-5, rtol=1e-5,
        )


@settings(max_examples=20, deadline=None)
@given(
    rows=st.sampled_from([8, 32, 100, 128, 256]),
    d=st.sampled_from([16, 32, 64]),
    f=st.sampled_from([32, 96, 160]),
    seed=st.integers(0, 10_000),
)
def test_swiglu_hypothesis_sweep(rows, d, f, seed):
    args = _swiglu_inputs(rows, d, f, seed)
    np.testing.assert_allclose(
        np.asarray(fused_swiglu_mlp(*args)),
        np.asarray(ref.swiglu_mlp_ref(*args)),
        atol=1e-5, rtol=1e-5,
    )


@settings(max_examples=20, deadline=None)
@given(
    rows=st.sampled_from([8, 32, 100, 128, 256]),
    d=st.sampled_from([16, 32, 64]),
    f=st.sampled_from([32, 96, 160]),
    seed=st.integers(0, 10_000),
)
def test_gelu_hypothesis_sweep(rows, d, f, seed):
    args = _gelu_inputs(rows, d, f, seed)
    np.testing.assert_allclose(
        np.asarray(fused_gelu_mlp(*args)),
        np.asarray(ref.gelu_mlp_ref(*args)),
        atol=1e-5, rtol=1e-5,
    )


def test_vmem_footprint_model():
    fp = mlp_vmem_footprint_bytes(256, 1024)
    assert 0 < fp < 16 * 1024 * 1024


def test_norm_refs_match_manual():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 32), jnp.float32)
    g = jnp.ones((32,))
    b = jnp.zeros((32,))
    ln = ref.layernorm_ref(x, g, b)
    np.testing.assert_allclose(np.asarray(ln.mean(-1)), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ln.std(-1)), 1.0, atol=1e-2)
    rn = ref.rmsnorm_ref(x, g)
    rms = np.sqrt((np.asarray(rn) ** 2).mean(-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-2)
