"""L2 correctness: stage fwd/bwd composition == full-model autodiff.

The critical invariant for the runtime is that chaining the per-stage
artifacts (embed_fwd -> stage_fwd* -> head_bwd -> stage_bwd* -> embed_bwd)
produces exactly the gradients of the monolithic model.  This is what makes
GWTF's claim "same theoretical convergence as SGD" (paper §VI Training
Convergence) hold for our runtime.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.model import ModelConfig


CFGS = [
    ModelConfig(family="gpt", vocab_size=64, d_model=32, n_heads=4, n_layers=4,
                seq_len=16, microbatch=2, blocks_per_stage=2),
    ModelConfig(family="llama", vocab_size=64, d_model=32, n_heads=4, n_layers=4,
                seq_len=16, microbatch=2, blocks_per_stage=2),
]


def _data(cfg, seed=0):
    k = jax.random.PRNGKey(seed)
    toks = jax.random.randint(jax.random.fold_in(k, 0), (cfg.microbatch, cfg.seq_len), 0, cfg.vocab_size)
    tgts = jax.random.randint(jax.random.fold_in(k, 1), (cfg.microbatch, cfg.seq_len), 0, cfg.vocab_size)
    return toks, tgts


def _tree_allclose(a, b, atol=1e-4):
    fa = jax.tree_util.tree_leaves(a)
    fb = jax.tree_util.tree_leaves(b)
    assert len(fa) == len(fb)
    for x, y in zip(fa, fb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=atol, rtol=1e-3)


@pytest.mark.parametrize("cfg", CFGS, ids=[c.family for c in CFGS])
class TestStageComposition:
    def test_pipelined_equals_monolithic_loss(self, cfg):
        params = model.full_init(0, cfg)
        toks, tgts = _data(cfg)
        # pipelined forward
        x = model.embed_fwd(params["embed"], toks, cfg)
        for sp in params["stages"]:
            x = model.stage_fwd(sp, x, cfg)
        loss_pipe = model.head_loss(params["head"], x, tgts, cfg)
        loss_full = model.full_fwd_loss(params, toks, tgts, cfg)
        np.testing.assert_allclose(float(loss_pipe), float(loss_full), atol=1e-5)

    def test_pipelined_equals_monolithic_grads(self, cfg):
        params = model.full_init(0, cfg)
        toks, tgts = _data(cfg)
        # monolithic grads
        gfull = jax.grad(lambda p: model.full_fwd_loss(p, toks, tgts, cfg))(params)

        # pipelined fwd with saved activations
        acts = [model.embed_fwd(params["embed"], toks, cfg)]
        for sp in params["stages"]:
            acts.append(model.stage_fwd(sp, acts[-1], cfg))
        dhead, dx, _loss = model.head_bwd(params["head"], acts[-1], tgts, cfg)
        dstages = []
        for i in reversed(range(len(params["stages"]))):
            dsp, dx = model.stage_bwd(params["stages"][i], acts[i], dx, cfg)
            dstages.insert(0, dsp)
        dembed = model.embed_bwd(params["embed"], toks, dx, cfg)

        _tree_allclose(dembed, gfull["embed"])
        _tree_allclose(dhead, gfull["head"])
        for got, exp in zip(dstages, gfull["stages"]):
            _tree_allclose(got, exp)

    def test_pallas_and_ref_losses_agree(self, cfg):
        import dataclasses
        cfg_ref = dataclasses.replace(cfg, use_pallas=False)
        params = model.full_init(0, cfg)
        toks, tgts = _data(cfg)
        lp = float(model.full_fwd_loss(params, toks, tgts, cfg))
        lr_ = float(model.full_fwd_loss(params, toks, tgts, cfg_ref))
        np.testing.assert_allclose(lp, lr_, atol=1e-4)

    def test_loss_decreases_under_sgd(self, cfg):
        params = model.full_init(0, cfg)
        toks, tgts = _data(cfg)
        step = jax.jit(lambda p, t, g: model.full_train_step(p, t, g, jnp.float32(0.5), cfg))
        l0 = float(model.full_fwd_loss(params, toks, tgts, cfg))
        loss = None
        for _ in range(15):
            params, loss = step(params, toks, tgts)
        assert float(loss) < l0

    def test_init_shapes(self, cfg):
        sp = model.stage_init(jnp.uint32(0), cfg)
        for leaf in jax.tree_util.tree_leaves(sp):
            assert leaf.shape[0] == cfg.blocks_per_stage
        ep = model.embed_init(jnp.uint32(0), cfg)
        assert ep["tok_emb"].shape == (cfg.vocab_size, cfg.d_model)
        hp = model.head_init(jnp.uint32(0), cfg)
        assert hp["w_out"].shape == (cfg.d_model, cfg.vocab_size)

    def test_init_deterministic(self, cfg):
        a = model.stage_init(jnp.uint32(7), cfg)
        b = model.stage_init(jnp.uint32(7), cfg)
        _tree_allclose(a, b, atol=0)

    def test_init_seed_sensitivity(self, cfg):
        a = model.stage_init(jnp.uint32(7), cfg)
        b = model.stage_init(jnp.uint32(8), cfg)
        diffs = [
            float(jnp.max(jnp.abs(x - y)))
            for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
        ]
        assert max(diffs) > 0


@pytest.mark.parametrize("cfg", CFGS, ids=[c.family for c in CFGS])
class TestUpdates:
    def test_sgd_update_formula(self, cfg):
        p = model.stage_init(jnp.uint32(0), cfg)
        g = jax.tree_util.tree_map(jnp.ones_like, p)
        newp = model.sgd_update(p, g, jnp.float32(0.1))
        for a, b in zip(jax.tree_util.tree_leaves(p), jax.tree_util.tree_leaves(newp)):
            np.testing.assert_allclose(np.asarray(a - 0.1), np.asarray(b), atol=1e-6)

    def test_adam_first_step_direction(self, cfg):
        p = model.head_init(jnp.uint32(0), cfg)
        m = jax.tree_util.tree_map(jnp.zeros_like, p)
        v = jax.tree_util.tree_map(jnp.zeros_like, p)
        g = jax.tree_util.tree_map(jnp.ones_like, p)
        newp, newm, newv = model.adam_update(p, m, v, g, jnp.float32(0.001), jnp.int32(1))
        # first Adam step with unit grads moves each weight by ~ -lr
        for a, b in zip(jax.tree_util.tree_leaves(p), jax.tree_util.tree_leaves(newp)):
            np.testing.assert_allclose(np.asarray(a - b), 0.001, atol=1e-5)


def test_param_count_matches_actual():
    for cfg in CFGS:
        params = model.full_init(0, cfg)
        actual = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params))
        assert actual == cfg.param_count(), (cfg.family, actual, cfg.param_count())


def test_activation_bytes():
    cfg = CFGS[0]
    assert cfg.activation_bytes() == cfg.microbatch * cfg.seq_len * cfg.d_model * 4


def test_nstages_property():
    assert CFGS[0].n_stages == 2


def test_bad_config_rejected():
    with pytest.raises(AssertionError):
        ModelConfig(family="gpt", d_model=100, n_heads=3)
    with pytest.raises(AssertionError):
        ModelConfig(family="nope")
    with pytest.raises(AssertionError):
        ModelConfig(n_layers=7, blocks_per_stage=2)
