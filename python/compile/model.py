"""L2: GPT-like and LLaMA-like transformer *stage* models in JAX.

The paper trains pipeline-parallel LLMs whose stages are hosted by
volunteer nodes.  This module defines the per-stage computations that the
Rust coordinator executes at runtime through PJRT:

- ``embed_fwd`` / ``embed_bwd``   — first stage (data node): token (+pos) embedding
- ``stage_fwd`` / ``stage_bwd``   — relay stage: ``blocks_per_stage`` transformer blocks
- ``head_loss`` / ``head_bwd``    — last stage (colocated with the first on the
  data node, as in the paper): final norm + LM head + cross-entropy loss
- ``*_init``                      — parameter initialization (seeded)
- ``sgd_update`` / ``adam_update`` — parameter updates (gradient averaging
  across data-parallel replicas happens in Rust)

Backward passes recompute the forward internally via ``jax.vjp``
(rematerialization), so the Rust side only ships ``(params, saved_input,
upstream_grad)`` — exactly the activation/gradient flow the paper routes
between nodes.

The attention and feed-forward hot-spots call the L1 Pallas kernels
(``kernels.attention``, ``kernels.fused_mlp``) through ``jax.custom_vjp``:
the forward runs the fused kernel, the backward differentiates the jnp
reference (numerically identical within test tolerance — see
``python/tests``).  Everything here is lowered ONCE by ``aot.py``; Python
never runs on the training path.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from .kernels.attention import flash_attention
from .kernels.fused_mlp import fused_gelu_mlp, fused_swiglu_mlp
from .kernels import ref

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static configuration for one model family at one size.

    The paper evaluates GPT-like and LLaMA-like models with
    ``d_model=1024`` and 16 layers; the default here is a CPU-scale
    reduction with the same layer structure (see DESIGN.md §Substitutions).
    Note: the paper says ``n_heads=18``, which does not divide 1024; we
    require ``d_model % n_heads == 0`` (DESIGN.md notes the discrepancy).
    """

    family: str = "llama"  # "gpt" | "llama"
    vocab_size: int = 2048
    d_model: int = 256
    n_heads: int = 8
    n_layers: int = 8
    d_ff: int = 0  # 0 -> family default (4*d for gpt, 8/3*d rounded for llama)
    seq_len: int = 128
    microbatch: int = 4
    blocks_per_stage: int = 2
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    use_pallas: bool = True
    init_std: float = 0.02

    def __post_init__(self):
        assert self.family in ("gpt", "llama"), self.family
        assert self.d_model % self.n_heads == 0, (self.d_model, self.n_heads)
        assert self.n_layers % self.blocks_per_stage == 0, (
            self.n_layers,
            self.blocks_per_stage,
        )
        if self.d_ff == 0:
            dff = 4 * self.d_model if self.family == "gpt" else (8 * self.d_model) // 3
            # round up to a multiple of 32 for MXU-friendly tiles
            dff = (dff + 31) // 32 * 32
            object.__setattr__(self, "d_ff", dff)

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def n_stages(self) -> int:
        """Number of relay (block) stages; embed/head live on the data node."""
        return self.n_layers // self.blocks_per_stage

    def param_count(self) -> int:
        """Total trainable parameters (for reporting / activation sizing)."""
        d, v, s = self.d_model, self.vocab_size, self.seq_len
        emb = v * d + (s * d if self.family == "gpt" else 0)
        if self.family == "gpt":
            blk = 4 * d * d + 2 * d * self.d_ff + self.d_ff + 5 * d
        else:
            blk = 4 * d * d + 3 * d * self.d_ff + 2 * d
        head = v * d + (2 * d if self.family == "gpt" else d)
        return emb + self.n_layers * blk + head

    def activation_bytes(self) -> int:
        """Bytes of one microbatch activation tensor shipped between stages."""
        return self.microbatch * self.seq_len * self.d_model * 4


# ---------------------------------------------------------------------------
# Kernel ops wrapped in custom_vjp: Pallas forward, reference backward.
# ---------------------------------------------------------------------------


@jax.custom_vjp
def _attn_op(q, k, v):
    return flash_attention(q, k, v, causal=True)


def _attn_op_fwd(q, k, v):
    return _attn_op(q, k, v), (q, k, v)


def _attn_op_bwd(res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda a, b, c: ref.attention_ref(a, b, c, causal=True), q, k, v)
    return vjp(g)


_attn_op.defvjp(_attn_op_fwd, _attn_op_bwd)


@jax.custom_vjp
def _swiglu_op(x, g, wg, wu, wd):
    return fused_swiglu_mlp(x, g, wg, wu, wd)


def _swiglu_op_fwd(x, g, wg, wu, wd):
    return _swiglu_op(x, g, wg, wu, wd), (x, g, wg, wu, wd)


def _swiglu_op_bwd(res, gr):
    _, vjp = jax.vjp(lambda *a: ref.swiglu_mlp_ref(*a), *res)
    return vjp(gr)


_swiglu_op.defvjp(_swiglu_op_fwd, _swiglu_op_bwd)


@jax.custom_vjp
def _gelu_mlp_op(x, g, b, w1, b1, w2, b2):
    return fused_gelu_mlp(x, g, b, w1, b1, w2, b2)


def _gelu_mlp_op_fwd(x, g, b, w1, b1, w2, b2):
    return _gelu_mlp_op(x, g, b, w1, b1, w2, b2), (x, g, b, w1, b1, w2, b2)


def _gelu_mlp_op_bwd(res, gr):
    _, vjp = jax.vjp(lambda *a: ref.gelu_mlp_ref(*a), *res)
    return vjp(gr)


_gelu_mlp_op.defvjp(_gelu_mlp_op_fwd, _gelu_mlp_op_bwd)


# ---------------------------------------------------------------------------
# Transformer blocks
# ---------------------------------------------------------------------------


def _split_heads(x: jax.Array, n_heads: int) -> jax.Array:
    b, s, d = x.shape
    return x.reshape(b, s, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x: jax.Array) -> jax.Array:
    b, h, s, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * dh)


def _attention(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Multi-head causal self-attention (with RoPE for the llama family)."""
    q = _split_heads(x @ p["wq"], cfg.n_heads)
    k = _split_heads(x @ p["wk"], cfg.n_heads)
    v = _split_heads(x @ p["wv"], cfg.n_heads)
    if cfg.family == "llama":
        q = ref.rope_ref(q, theta=cfg.rope_theta)
        k = ref.rope_ref(k, theta=cfg.rope_theta)
    if cfg.use_pallas:
        o = _attn_op(q, k, v)
    else:
        o = ref.attention_ref(q, k, v, causal=True)
    return _merge_heads(o) @ p["wo"]


def block_fwd(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """One pre-norm transformer block; ``p`` holds one block's params."""
    b, s, d = x.shape
    if cfg.family == "gpt":
        xn = ref.layernorm_ref(x, p["ln1_g"], p["ln1_b"], eps=cfg.norm_eps)
        h = x + _attention(p, xn, cfg)
        flat = h.reshape(b * s, d)
        if cfg.use_pallas:
            m = _gelu_mlp_op(flat, p["ln2_g"], p["ln2_b"], p["w1"], p["b1"], p["w2"], p["b2"])
        else:
            m = ref.gelu_mlp_ref(flat, p["ln2_g"], p["ln2_b"], p["w1"], p["b1"], p["w2"], p["b2"])
        return h + m.reshape(b, s, d)
    else:
        xn = ref.rmsnorm_ref(x, p["attn_norm"], eps=cfg.norm_eps)
        h = x + _attention(p, xn, cfg)
        flat = h.reshape(b * s, d)
        if cfg.use_pallas:
            m = _swiglu_op(flat, p["mlp_norm"], p["w_gate"], p["w_up"], p["w_down"])
        else:
            m = ref.swiglu_mlp_ref(flat, p["mlp_norm"], p["w_gate"], p["w_up"], p["w_down"])
        return h + m.reshape(b, s, d)


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def _normal(key, shape, std):
    return (jax.random.normal(key, shape, dtype=jnp.float32) * std).astype(jnp.float32)


def block_init(key: jax.Array, cfg: ModelConfig) -> Params:
    d, dff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 8)
    common = {
        "wq": _normal(ks[0], (d, d), cfg.init_std),
        "wk": _normal(ks[1], (d, d), cfg.init_std),
        "wv": _normal(ks[2], (d, d), cfg.init_std),
        "wo": _normal(ks[3], (d, d), cfg.init_std),
    }
    if cfg.family == "gpt":
        return dict(
            common,
            ln1_g=jnp.ones((d,), jnp.float32),
            ln1_b=jnp.zeros((d,), jnp.float32),
            ln2_g=jnp.ones((d,), jnp.float32),
            ln2_b=jnp.zeros((d,), jnp.float32),
            w1=_normal(ks[4], (d, dff), cfg.init_std),
            b1=jnp.zeros((dff,), jnp.float32),
            w2=_normal(ks[5], (dff, d), cfg.init_std),
            b2=jnp.zeros((d,), jnp.float32),
        )
    return dict(
        common,
        attn_norm=jnp.ones((d,), jnp.float32),
        mlp_norm=jnp.ones((d,), jnp.float32),
        w_gate=_normal(ks[4], (d, dff), cfg.init_std),
        w_up=_normal(ks[5], (d, dff), cfg.init_std),
        w_down=_normal(ks[6], (dff, d), cfg.init_std),
    )


def stage_init(seed: jax.Array, cfg: ModelConfig) -> Params:
    """Stacked params for ``blocks_per_stage`` blocks (leading axis = block)."""
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, cfg.blocks_per_stage)
    return jax.vmap(lambda k: block_init(k, cfg))(keys)


def embed_init(seed: jax.Array, cfg: ModelConfig) -> Params:
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    p = {"tok_emb": _normal(k1, (cfg.vocab_size, cfg.d_model), cfg.init_std)}
    if cfg.family == "gpt":
        p["pos_emb"] = _normal(k2, (cfg.seq_len, cfg.d_model), cfg.init_std)
    return p


def head_init(seed: jax.Array, cfg: ModelConfig) -> Params:
    key = jax.random.PRNGKey(seed)
    d = cfg.d_model
    p = {"w_out": _normal(key, (d, cfg.vocab_size), cfg.init_std)}
    p["norm_g"] = jnp.ones((d,), jnp.float32)
    if cfg.family == "gpt":
        p["norm_b"] = jnp.zeros((d,), jnp.float32)
    return p


# ---------------------------------------------------------------------------
# Stage-level forward / backward (what gets AOT-lowered)
# ---------------------------------------------------------------------------


def embed_fwd(p: Params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    """tokens (B, S) int32 -> activations (B, S, D) f32."""
    x = p["tok_emb"][tokens]
    if cfg.family == "gpt":
        x = x + p["pos_emb"][None, : tokens.shape[1], :]
    return x


def stage_fwd(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Run ``blocks_per_stage`` stacked blocks via scan."""

    def step(h, blk_params):
        return block_fwd(blk_params, h, cfg), None

    y, _ = jax.lax.scan(step, x, p)
    return y


def head_loss(p: Params, x: jax.Array, targets: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Final norm + LM head + mean cross-entropy. targets (B, S) int32."""
    if cfg.family == "gpt":
        xn = ref.layernorm_ref(x, p["norm_g"], p["norm_b"], eps=cfg.norm_eps)
    else:
        xn = ref.rmsnorm_ref(x, p["norm_g"], eps=cfg.norm_eps)
    logits = xn @ p["w_out"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def stage_bwd(
    p: Params, x: jax.Array, dy: jax.Array, cfg: ModelConfig
) -> Tuple[Params, jax.Array]:
    """(dparams, dx) — recomputes the forward (rematerialization)."""
    _, vjp = jax.vjp(lambda pp, xx: stage_fwd(pp, xx, cfg), p, x)
    return vjp(dy)


def head_bwd(
    p: Params, x: jax.Array, targets: jax.Array, cfg: ModelConfig
) -> Tuple[Params, jax.Array, jax.Array]:
    """(dparams, dx, loss) for the head stage (dloss = 1)."""
    loss, vjp = jax.vjp(lambda pp, xx: head_loss(pp, xx, targets, cfg), p, x)
    dp, dx = vjp(jnp.float32(1.0))
    return dp, dx, loss


def embed_bwd(p: Params, tokens: jax.Array, dx: jax.Array, cfg: ModelConfig) -> Params:
    """dparams for the embedding stage."""
    _, vjp = jax.vjp(lambda pp: embed_fwd(pp, tokens, cfg), p)
    (dp,) = vjp(dx)
    return dp


def sgd_update(params: Params, grads: Params, lr: jax.Array) -> Params:
    """Plain SGD — the paper's convergence claim is equivalence to SGD."""
    return jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)


def adam_update(
    params: Params,
    m: Params,
    v: Params,
    grads: Params,
    lr: jax.Array,
    step: jax.Array,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
) -> Tuple[Params, Params, Params]:
    """Adam (bias-corrected); optional optimizer for the convergence runs."""
    stepf = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** stepf
    bc2 = 1.0 - b2 ** stepf

    new_p, new_m, new_v = {}, {}, {}
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_m = jax.tree_util.tree_leaves(m)
    flat_v = jax.tree_util.tree_leaves(v)
    flat_g = jax.tree_util.tree_leaves(grads)
    out_p, out_m, out_v = [], [], []
    for p, mm, vv, g in zip(flat_p, flat_m, flat_v, flat_g):
        mm = b1 * mm + (1.0 - b1) * g
        vv = b2 * vv + (1.0 - b2) * g * g
        out_p.append(p - lr * (mm / bc1) / (jnp.sqrt(vv / bc2) + eps))
        out_m.append(mm)
        out_v.append(vv)
    unflatten = jax.tree_util.tree_unflatten
    return unflatten(treedef, out_p), unflatten(treedef, out_m), unflatten(treedef, out_v)


# ---------------------------------------------------------------------------
# Full-model composition (used by tests and by the centralized baseline of
# the Fig. 6 convergence experiment).
# ---------------------------------------------------------------------------


def full_init(seed: int, cfg: ModelConfig) -> Params:
    return {
        "embed": embed_init(jnp.uint32(seed), cfg),
        "stages": [stage_init(jnp.uint32(seed + 1 + i), cfg) for i in range(cfg.n_stages)],
        "head": head_init(jnp.uint32(seed + 101), cfg),
    }


def full_fwd_loss(params: Params, tokens: jax.Array, targets: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = embed_fwd(params["embed"], tokens, cfg)
    for sp in params["stages"]:
        x = stage_fwd(sp, x, cfg)
    return head_loss(params["head"], x, targets, cfg)


def full_train_step(
    params: Params, tokens: jax.Array, targets: jax.Array, lr: jax.Array, cfg: ModelConfig
) -> Tuple[Params, jax.Array]:
    loss, grads = jax.value_and_grad(lambda p: full_fwd_loss(p, tokens, targets, cfg))(params)
    return sgd_update(params, grads, lr), loss
