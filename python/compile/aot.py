"""AOT lowering: JAX stage functions -> HLO text artifacts for the Rust runtime.

HLO *text* (NOT ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Every exported function is flattened to a positional-array signature
(pytrees are flattened in ``jax.tree_util`` order) and lowered with
``return_tuple=True``.  ``artifacts/manifest.json`` records, per artifact,
the exact input/output shapes+dtypes and the parameter-leaf names in
flattening order, which is what ``rust/src/runtime`` uses to drive
execution.

Usage (normally via ``make artifacts``):

    cd python && python -m compile.aot --out-dir ../artifacts \
        --family both --preset small
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
from typing import Any, Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .model import ModelConfig

#: Named size presets. "small" is the CPU-scale default used by the test
#: suite and simulator benches; "gpt2s" is the ~110M-parameter configuration
#: for the end-to-end convergence run (Fig. 6 / EXPERIMENTS.md).
PRESETS: Dict[str, Dict[str, Any]] = {
    "tiny": dict(vocab_size=256, d_model=64, n_heads=4, n_layers=4, seq_len=32, microbatch=2, blocks_per_stage=2),
    "small": dict(vocab_size=2048, d_model=256, n_heads=8, n_layers=8, seq_len=128, microbatch=4, blocks_per_stage=2),
    "medium": dict(vocab_size=4096, d_model=512, n_heads=8, n_layers=12, seq_len=128, microbatch=4, blocks_per_stage=3),
    "gpt2s": dict(vocab_size=8192, d_model=768, n_heads=12, n_layers=12, seq_len=128, microbatch=4, blocks_per_stage=2),
}


def to_hlo_text(lowered) -> str:
    """Convert a jax Lowered to XLA HLO text (the Rust-loadable format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _leaf_names(tree: Any) -> List[str]:
    """Dot-joined key-path names of the leaves in flattening order."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    names = []
    for path, _leaf in flat:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        names.append(".".join(parts))
    return names


def _spec(leaf) -> Dict[str, Any]:
    return {"shape": list(leaf.shape), "dtype": str(leaf.dtype)}


def export_fn(
    fn: Callable,
    example_args: Tuple[Any, ...],
    name: str,
    out_dir: str,
) -> Dict[str, Any]:
    """Flatten ``fn``'s pytree signature, lower to HLO text, write artifact.

    Returns the manifest entry (input/output specs + file name + sha256).
    """
    flat, treedef = jax.tree_util.tree_flatten(example_args)

    def wrapped(*flat_args):
        args = jax.tree_util.tree_unflatten(treedef, list(flat_args))
        out = fn(*args)
        return tuple(jax.tree_util.tree_leaves(out))

    out_shapes = jax.eval_shape(wrapped, *flat)
    lowered = jax.jit(wrapped).lower(*flat)
    text = to_hlo_text(lowered)

    # jax prunes arguments the computation never reads (e.g. the embedding
    # table in embed_bwd); the runtime must pass only the kept ones.
    try:
        kept = sorted(lowered._lowering.compile_args["kept_var_idx"])
    except (AttributeError, KeyError):
        kept = list(range(len(flat)))

    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)

    return {
        "file": f"{name}.hlo.txt",
        "inputs": [_spec(l) for l in flat],
        "input_names": _leaf_names(example_args),
        "kept_inputs": kept,
        "outputs": [_spec(l) for l in out_shapes],
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
        "hlo_bytes": len(text),
    }


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def family_exports(cfg: ModelConfig) -> Dict[str, Tuple[Callable, Tuple[Any, ...]]]:
    """All (function, example-args) pairs to lower for one model family."""
    B, S, D, V = cfg.microbatch, cfg.seq_len, cfg.d_model, cfg.vocab_size
    seed = _sds((), jnp.uint32)
    tokens = _sds((B, S), jnp.int32)
    targets = _sds((B, S), jnp.int32)
    acts = _sds((B, S, D), jnp.float32)
    lr = _sds((), jnp.float32)

    eparams = jax.eval_shape(lambda s: model.embed_init(s, cfg), seed)
    sparams = jax.eval_shape(lambda s: model.stage_init(s, cfg), seed)
    hparams = jax.eval_shape(lambda s: model.head_init(s, cfg), seed)

    exports: Dict[str, Tuple[Callable, Tuple[Any, ...]]] = {
        "embed_init": (lambda s: model.embed_init(s, cfg), (seed,)),
        "stage_init": (lambda s: model.stage_init(s, cfg), (seed,)),
        "head_init": (lambda s: model.head_init(s, cfg), (seed,)),
        "embed_fwd": (lambda p, t: model.embed_fwd(p, t, cfg), (eparams, tokens)),
        "stage_fwd": (lambda p, x: model.stage_fwd(p, x, cfg), (sparams, acts)),
        "stage_bwd": (lambda p, x, dy: model.stage_bwd(p, x, dy, cfg), (sparams, acts, acts)),
        "head_loss": (lambda p, x, t: model.head_loss(p, x, t, cfg), (hparams, acts, targets)),
        "head_bwd": (lambda p, x, t: model.head_bwd(p, x, t, cfg), (hparams, acts, targets)),
        "embed_bwd": (lambda p, t, dx: model.embed_bwd(p, t, dx, cfg), (eparams, tokens, acts)),
        "embed_update": (model.sgd_update, (eparams, eparams, lr)),
        "stage_update": (model.sgd_update, (sparams, sparams, lr)),
        "head_update": (model.sgd_update, (hparams, hparams, lr)),
    }
    return exports


def config_fingerprint(cfg: ModelConfig, families: Sequence[str]) -> str:
    payload = json.dumps(
        {"cfg": dataclasses.asdict(cfg), "families": list(families), "v": 4},
        sort_keys=True,
    )
    return hashlib.sha256(payload.encode()).hexdigest()


def build_artifacts(
    out_dir: str,
    families: Sequence[str],
    base_cfg: ModelConfig,
    force: bool = False,
) -> Dict[str, Any]:
    """Lower everything; skip if the manifest fingerprint already matches."""
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")
    fingerprint = config_fingerprint(base_cfg, families)

    if not force and os.path.exists(manifest_path):
        try:
            with open(manifest_path) as f:
                existing = json.load(f)
            if existing.get("fingerprint") == fingerprint and all(
                os.path.exists(os.path.join(out_dir, e["file"]))
                for fam in existing.get("families", {}).values()
                for e in fam["artifacts"].values()
            ):
                print(f"artifacts up to date ({out_dir}); skipping")
                return existing
        except (json.JSONDecodeError, KeyError):
            pass

    manifest: Dict[str, Any] = {
        "fingerprint": fingerprint,
        "families": {},
    }
    for family in families:
        cfg = dataclasses.replace(base_cfg, family=family)
        entries = {}
        for name, (fn, args) in family_exports(cfg).items():
            art_name = f"{family}_{name}"
            print(f"lowering {art_name} ...", flush=True)
            entries[name] = export_fn(fn, args, art_name, out_dir)
        manifest["families"][family] = {
            "config": dataclasses.asdict(cfg),
            "param_count": cfg.param_count(),
            "activation_bytes": cfg.activation_bytes(),
            "n_stages": cfg.n_stages,
            "artifacts": entries,
        }

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    total = sum(
        e["hlo_bytes"]
        for fam in manifest["families"].values()
        for e in fam["artifacts"].values()
    )
    print(f"wrote {manifest_path} ({total/1e6:.1f} MB of HLO text)")
    return manifest


def parse_config(argv=None) -> Tuple[argparse.Namespace, ModelConfig]:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--family", default="both", choices=["gpt", "llama", "both"])
    ap.add_argument("--preset", default="small", choices=sorted(PRESETS))
    ap.add_argument("--vocab-size", type=int)
    ap.add_argument("--d-model", type=int)
    ap.add_argument("--n-heads", type=int)
    ap.add_argument("--n-layers", type=int)
    ap.add_argument("--d-ff", type=int)
    ap.add_argument("--seq-len", type=int)
    ap.add_argument("--microbatch", type=int)
    ap.add_argument("--blocks-per-stage", type=int)
    ap.add_argument("--no-pallas", action="store_true", help="lower the jnp reference instead of the Pallas kernels")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    kw = dict(PRESETS[args.preset])
    for field in ("vocab_size", "d_model", "n_heads", "n_layers", "d_ff", "seq_len", "microbatch", "blocks_per_stage"):
        v = getattr(args, field)
        if v is not None:
            kw[field] = v
    if args.no_pallas:
        kw["use_pallas"] = False
    return args, ModelConfig(**kw)


def main(argv=None) -> None:
    args, cfg = parse_config(argv)
    families = ["gpt", "llama"] if args.family == "both" else [args.family]
    build_artifacts(args.out_dir, families, cfg, force=args.force)


if __name__ == "__main__":
    main()
