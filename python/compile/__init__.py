"""Build-time compile path: L2 JAX model + L1 Pallas kernels + AOT lowering.

Nothing in this package runs at training time; ``aot.py`` lowers the stage
functions to HLO text once and the Rust runtime takes over.
"""
