"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has a reference here; ``python/tests``
asserts allclose between kernel and reference across shape/dtype sweeps
(hypothesis).  These are also what L2 falls back to when a kernel is
disabled (``use_pallas=False``), so the lowered HLO of model.py can be
diffed kernel-vs-reference.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True) -> jax.Array:
    """softmax(Q K^T / sqrt(d)) V over (batch, heads, seq, d_head)."""
    d_head = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) / math.sqrt(d_head)
    if causal:
        seq = q.shape[2]
        mask = jnp.tril(jnp.ones((seq, seq), dtype=bool))
        s = jnp.where(mask[None, None, :, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def rmsnorm_ref(x: jax.Array, g: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * rms * g.astype(jnp.float32)).astype(x.dtype)


def layernorm_ref(x: jax.Array, g: jax.Array, b: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mean) ** 2, axis=-1, keepdims=True)
    xn = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (xn * g.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def swiglu_mlp_ref(
    x: jax.Array,
    g: jax.Array,
    w_gate: jax.Array,
    w_up: jax.Array,
    w_down: jax.Array,
    *,
    eps: float = 1e-5,
) -> jax.Array:
    """RMSNorm + SwiGLU feed-forward; oracle for ``fused_swiglu_mlp``."""
    xn = rmsnorm_ref(x, g, eps=eps).astype(jnp.float32)
    h = jax.nn.silu(xn @ w_gate.astype(jnp.float32)) * (xn @ w_up.astype(jnp.float32))
    return (h @ w_down.astype(jnp.float32)).astype(x.dtype)


def gelu_mlp_ref(
    x: jax.Array,
    g: jax.Array,
    b: jax.Array,
    w1: jax.Array,
    b1: jax.Array,
    w2: jax.Array,
    b2: jax.Array,
    *,
    eps: float = 1e-5,
) -> jax.Array:
    """LayerNorm + GELU feed-forward; oracle for ``fused_gelu_mlp``."""
    xn = layernorm_ref(x, g, b, eps=eps).astype(jnp.float32)
    h = jax.nn.gelu(xn @ w1.astype(jnp.float32) + b1.astype(jnp.float32), approximate=True)
    return (h @ w2.astype(jnp.float32) + b2.astype(jnp.float32)).astype(x.dtype)


def rope_ref(x: jax.Array, *, theta: float = 10000.0) -> jax.Array:
    """Rotary position embedding over (batch, heads, seq, d_head)."""
    _, _, seq, d_head = x.shape
    half = d_head // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = jnp.arange(seq, dtype=jnp.float32)[:, None] * freqs[None, :]
    cos = jnp.cos(angles)[None, None, :, :]
    sin = jnp.sin(angles)[None, None, :, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
