"""L1 Pallas kernels: fused (norm + MLP) transformer feed-forward blocks.

Two variants matching the two model families in the paper's evaluation:

- ``fused_swiglu_mlp`` — LLaMA-like: RMSNorm -> (gate, up) -> SiLU(gate)*up
  -> down projection, all in one kernel so the normalized activations never
  round-trip to HBM.
- ``fused_gelu_mlp`` — GPT-like: LayerNorm -> fc -> GELU -> proj.

Rows of the token stream are tiled via BlockSpec (``block_rows`` tokens per
grid step resident in VMEM); the weight matrices stay whole so the two/three
matmuls hit the MXU back-to-back.  ``interpret=True`` lowers to plain HLO
for the CPU PJRT runtime.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .attention import pick_block

#: Default token-rows tile per grid step.
DEFAULT_BLOCK_ROWS = 128


def _swiglu_kernel(x_ref, g_ref, wg_ref, wu_ref, wd_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    # RMSNorm over the model dim.
    rms = jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    xn = x * rms * g
    gate = jnp.dot(xn, wg_ref[...].astype(jnp.float32))
    up = jnp.dot(xn, wu_ref[...].astype(jnp.float32))
    h = jax.nn.silu(gate) * up
    out = jnp.dot(h, wd_ref[...].astype(jnp.float32))
    o_ref[...] = out.astype(o_ref.dtype)


def _gelu_kernel(x_ref, g_ref, b_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    xn = (x - mean) * jax.lax.rsqrt(var + eps)
    xn = xn * g_ref[...].astype(jnp.float32) + b_ref[...].astype(jnp.float32)
    h = jnp.dot(xn, w1_ref[...].astype(jnp.float32)) + b1_ref[...].astype(jnp.float32)
    h = jax.nn.gelu(h, approximate=True)
    out = jnp.dot(h, w2_ref[...].astype(jnp.float32)) + b2_ref[...].astype(jnp.float32)
    o_ref[...] = out.astype(o_ref.dtype)


def fused_swiglu_mlp(
    x: jax.Array,
    g: jax.Array,
    w_gate: jax.Array,
    w_up: jax.Array,
    w_down: jax.Array,
    *,
    eps: float = 1e-5,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> jax.Array:
    """RMSNorm + SwiGLU MLP over ``x: (rows, d_model)``.

    ``g: (d_model,)`` RMSNorm weight, ``w_gate/w_up: (d_model, d_ff)``,
    ``w_down: (d_ff, d_model)``.  Reference: ``ref.swiglu_mlp_ref``.
    """
    rows, d_model = x.shape
    d_ff = w_gate.shape[1]
    br = pick_block(rows, block_rows)
    grid = (rows // br,)

    return pl.pallas_call(
        functools.partial(_swiglu_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d_model), lambda i: (i, 0)),
            pl.BlockSpec((d_model,), lambda i: (0,)),
            pl.BlockSpec((d_model, d_ff), lambda i: (0, 0)),
            pl.BlockSpec((d_model, d_ff), lambda i: (0, 0)),
            pl.BlockSpec((d_ff, d_model), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, d_model), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, g, w_gate, w_up, w_down)


def fused_gelu_mlp(
    x: jax.Array,
    g: jax.Array,
    b: jax.Array,
    w1: jax.Array,
    b1: jax.Array,
    w2: jax.Array,
    b2: jax.Array,
    *,
    eps: float = 1e-5,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> jax.Array:
    """LayerNorm + GELU MLP over ``x: (rows, d_model)``.

    ``g/b: (d_model,)`` LayerNorm affine, ``w1: (d_model, d_ff)``,
    ``b1: (d_ff,)``, ``w2: (d_ff, d_model)``, ``b2: (d_model,)``.
    Reference: ``ref.gelu_mlp_ref``.
    """
    rows, d_model = x.shape
    d_ff = w1.shape[1]
    br = pick_block(rows, block_rows)
    grid = (rows // br,)

    return pl.pallas_call(
        functools.partial(_gelu_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d_model), lambda i: (i, 0)),
            pl.BlockSpec((d_model,), lambda i: (0,)),
            pl.BlockSpec((d_model,), lambda i: (0,)),
            pl.BlockSpec((d_model, d_ff), lambda i: (0, 0)),
            pl.BlockSpec((d_ff,), lambda i: (0,)),
            pl.BlockSpec((d_ff, d_model), lambda i: (0, 0)),
            pl.BlockSpec((d_model,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d_model), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, g, b, w1, b1, w2, b2)


def mlp_vmem_footprint_bytes(
    d_model: int, d_ff: int, *, block_rows: int = DEFAULT_BLOCK_ROWS, dtype_bytes: int = 4
) -> int:
    """VMEM bytes per grid step: row tile + whole weights + hidden tile."""
    x_tile = block_rows * d_model * dtype_bytes
    weights = (2 * d_model * d_ff + d_ff * d_model + d_model) * dtype_bytes
    hidden = block_rows * d_ff * 4
    return x_tile + weights + hidden + x_tile
