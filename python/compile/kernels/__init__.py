"""L1 Pallas kernels (interpret=True) and their pure-jnp reference oracles."""

from . import ref  # noqa: F401
from .attention import flash_attention  # noqa: F401
from .fused_mlp import fused_gelu_mlp, fused_swiglu_mlp  # noqa: F401
