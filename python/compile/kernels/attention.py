"""L1 Pallas kernel: fused multi-head causal attention (FlashAttention-style).

TPU adaptation of the transformer hot-spot (see DESIGN.md §Hardware-Adaptation):
Q is tiled into VMEM-resident blocks via BlockSpec, K/V are streamed in
``block_k`` tiles, and the online-softmax running max / denominator is kept
in fp32 registers — the TPU analogue of FlashAttention's shared-memory
tiling (VMEM plays the scratchpad role, the MXU consumes the
(block_q x d_head) @ (d_head x block_k) matmuls).

Lowered with ``interpret=True`` so the kernel becomes plain HLO that the
CPU PJRT client in the Rust runtime can execute.  Real-TPU performance is
estimated from the VMEM footprint of these block shapes in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Default query tile (rows of Q resident in VMEM per grid step).
DEFAULT_BLOCK_Q = 128
#: Default key/value tile streamed per inner-loop step.
DEFAULT_BLOCK_K = 128


def pick_block(seq_len: int, preferred: int) -> int:
    """Largest divisor of ``seq_len`` that is <= ``preferred``.

    Pallas BlockSpecs require the grid to tile the array exactly; padding
    would waste MXU cycles, so we snap to a divisor instead.
    """
    b = min(preferred, seq_len)
    while seq_len % b != 0:
        b -= 1
    return b


def _attention_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    *,
    scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    seq_len: int,
):
    """One (batch, head, q-tile) grid step of online-softmax attention."""
    q_blk = pl.program_id(2)
    d_head = q_ref.shape[-1]

    # fp32 accumulation regardless of input dtype (MXU-friendly on TPU,
    # numerically required for the online softmax).
    q = q_ref[0, 0, :, :].astype(jnp.float32) * scale

    m0 = jnp.full((block_q,), -jnp.inf, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, d_head), dtype=jnp.float32)

    num_k_blocks = seq_len // block_k
    if causal:
        # K tiles strictly after this Q tile are fully masked: skip them.
        last_q_pos = (q_blk + 1) * block_q - 1
        k_upper = jax.lax.div(last_q_pos, block_k) + 1
    else:
        k_upper = num_k_blocks

    def body(i, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (0, 0, pl.ds(i * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (0, 0, pl.ds(i * block_k, block_k), slice(None)))
        k = k.astype(jnp.float32)
        v = v.astype(jnp.float32)

        s = jnp.dot(q, k.T)  # (block_q, block_k)
        if causal:
            q_pos = q_blk * block_q + jnp.arange(block_q)
            k_pos = i * block_k + jnp.arange(block_k)
            mask = q_pos[:, None] >= k_pos[None, :]
            s = jnp.where(mask, s, -jnp.inf)

        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        # m_new is finite for every row the causal loop visits (the diagonal
        # element is always unmasked), so exp() below never sees inf-inf.
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + jnp.dot(p, v)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, k_upper, body, (m0, l0, acc0))
    out = acc / l[:, None]
    o_ref[0, 0, :, :] = out.astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jax.Array:
    """Fused attention over ``(batch, heads, seq, d_head)`` tensors.

    Numerically equivalent to ``ref.attention_ref`` (softmax(QK^T/sqrt(d))V
    with optional causal mask); validated against it by
    ``python/tests/test_attention.py``.
    """
    batch, heads, seq_len, d_head = q.shape
    assert k.shape == (batch, heads, seq_len, d_head), k.shape
    assert v.shape == (batch, heads, seq_len, d_head), v.shape

    bq = pick_block(seq_len, block_q)
    bk = pick_block(seq_len, block_k)
    grid = (batch, heads, seq_len // bq)
    scale = 1.0 / math.sqrt(d_head)

    kernel = functools.partial(
        _attention_kernel,
        scale=scale,
        causal=causal,
        block_q=bq,
        block_k=bk,
        seq_len=seq_len,
    )

    q_spec = pl.BlockSpec((1, 1, bq, d_head), lambda b, h, i: (b, h, i, 0))
    kv_spec = pl.BlockSpec((1, 1, seq_len, d_head), lambda b, h, i: (b, h, 0, 0))
    o_spec = pl.BlockSpec((1, 1, bq, d_head), lambda b, h, i: (b, h, i, 0))

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)


def vmem_footprint_bytes(
    seq_len: int,
    d_head: int,
    *,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    dtype_bytes: int = 4,
) -> int:
    """Estimated VMEM bytes resident per grid step (perf model, DESIGN.md §Perf).

    q tile + full-seq K/V stream buffers (double-buffered block_k tiles) +
    fp32 accumulator/stats + output tile.
    """
    bq = pick_block(seq_len, block_q)
    bk = pick_block(seq_len, block_k)
    q_tile = bq * d_head * dtype_bytes
    kv_stream = 2 * 2 * bk * d_head * dtype_bytes  # K and V, double-buffered
    acc = bq * d_head * 4 + 2 * bq * 4  # fp32 acc + m + l
    o_tile = bq * d_head * dtype_bytes
    return q_tile + kv_stream + acc + o_tile


def mxu_utilization_estimate(seq_len: int, d_head: int, *, block_q: int = DEFAULT_BLOCK_Q) -> float:
    """Crude MXU efficiency estimate: fraction of 128-aligned tile dims.

    The MXU is a 128x128 systolic array; dims that are multiples of 128 run
    at full occupancy, smaller dims pro-rate.
    """
    bq = pick_block(seq_len, block_q)
    eff_q = min(bq, 128) / 128.0
    eff_d = min(d_head, 128) / 128.0
    return eff_q * eff_d
